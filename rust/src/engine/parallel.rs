//! Partitioned event domains: deterministic intra-scenario parallelism.
//!
//! [`run_partitioned`] splits one simulation across worker threads. The
//! fabric is graph-cut into event domains (`interconnect::Partition`,
//! balanced by expected traffic — spine switches count for more than leaf
//! endpoints — with the PR 4 node-count rule kept as the A/B oracle);
//! each domain owns its nodes' components, a private ladder [`EventQueue`],
//! a private `NetState` shard (it only ever touches the link directions
//! whose **sender** lives in the domain — every `transmit` happens on the
//! forwarding node's side), and the per-node schedule/txn counters of its
//! nodes. Cross-domain packets travel through bounded SPSC channels and
//! are exchanged at a conservative barrier.
//!
//! ## Sparse neighbor exchange
//!
//! Cross-domain events are only ever born from a `forward` over a cut
//! link: components schedule their own timers/self-events locally, and
//! contracted links (half-duplex, zero-latency) never cross a cut. So a
//! domain can only ever need to talk to the domains it shares cut links
//! with — the exchange opens channels for exactly those pairs
//! ([`Partition::exchange_peers`]) instead of the previous all-to-all
//! mesh. On a spine-leaf cut the peer graph is nearly a star around the
//! spine domains, so channel count drops from `ndom * (ndom - 1)` to
//! roughly `2 * ndom`. The accounting lands in [`IntraStats`]
//! (`Engine::intra_stats`).
//!
//! ## Barrier modes
//!
//! [`BarrierMode::FixedWindow`] is the PR 4/5 lockstep protocol: every
//! round, every domain drains `[.., tmin + lookahead)` and sends exactly
//! one message per neighbor channel (a compact [`Msg::Quiet`] token when
//! it has no traffic), then receives one from each. Simple, but on a
//! 162-node spine-leaf 8-domain run more than half the barrier traffic
//! is quiet tokens, and event-free stretches still cost one lookahead
//! per round.
//!
//! [`BarrierMode::Adaptive`] (the default) removes both costs without
//! touching the event order:
//!
//! * **Adaptive window widening.** The coordinator keeps, per domain,
//!   the earliest time it could possibly act: `seed[d] = min(next local
//!   event, earliest in-flight batch headed to d)`. A min-plus
//!   relaxation of the seeds over the cut-neighbor graph (edge weight =
//!   minimum cut-link latency between the pair, [`Partition::
//!   horizon_graph`]) yields `dist[d]`, the earliest time domain `d`
//!   could process *any* event this round — then `H[d] = min over peers
//!   p of (dist[p] + lat(p, d))` is a certified lower bound on the next
//!   inbound arrival, covering multi-hop relays (a relay chain through
//!   `p` only adds latency). Each domain drains `[.., H[d])`: a domain
//!   whose neighbors are quiet far into the future jumps many
//!   lookaheads in one barrier round (`IntraStats::widened_windows`).
//!   `H[d] >= tmin + lookahead` always, so no round is ever narrower
//!   than the fixed-window protocol's.
//! * **Quiet-run elision.** A domain with nothing to do this round
//!   (`seed[d] >= H[d]`, e.g. an empty queue) is simply not scheduled:
//!   its one report already published its horizon, and the coordinator
//!   leaves it parked until a neighbor actually sends it a batch. Only
//!   non-empty event batches ever cross a channel — quiet tokens are
//!   elided entirely (`IntraStats::elided_tokens`), and batches are
//!   delivered at the *start* of the receiver's next round, before its
//!   drain. Senders report the minimum event time of each batch so the
//!   coordinator can fold in-flight events into the seeds.
//!
//! ## Why the result is byte-identical to the sequential engine
//!
//! * Every event's key `(time, src, seq)` is minted from the scheduling
//!   node's private counter — identical in both engines as long as each
//!   node's handlers run in the same order with the same inputs.
//! * Fixed windows: the barrier advances in windows `[.., tmin +
//!   lookahead)` where `tmin` is the globally earliest pending event and
//!   `lookahead` the minimum propagation latency over cut links
//!   (saturating add: disconnected multi-domain fabrics have no cut
//!   links and an unbounded `Ps::MAX` lookahead). Any cross-domain
//!   packet sent during a window departs at `>= tmin`, so it arrives at
//!   `>= tmin + lookahead` — never inside the window.
//! * Adaptive windows generalize the same argument per domain: every
//!   event domain `p` processes this round departs at `>= seed[p] >=
//!   dist[p]`, so anything it sends (or relays) toward `d` arrives at
//!   `>= dist[p] + lat(p, d) >= H[d]` — never inside `d`'s window
//!   `[.., H[d])`. Hence when a domain drains its window in key order,
//!   it interleaves its events exactly as the sequential engine's
//!   global key order would have. The worker asserts the property at
//!   every delivery (no batch event behind the receiver's drained
//!   horizon), and `esf check` rule ESF-C013 proves the horizon graph
//!   the relaxation runs on mirrors the physical cut set.
//! * Handler side effects stay inside the domain: components, owned link
//!   directions, per-node counters. Half-duplex links (shared medium) and
//!   zero-latency links are never cut, by construction of the partition.
//! * The domain weighting only moves nodes between domains; every
//!   weighting yields the same per-node event streams, so the model is
//!   free to chase balance without touching output (pinned in
//!   `tests/partition.rs`).
//!
//! Warm-up runs sequentially: the epoch flip (`warmup_done`) is a global
//! zero-latency effect that no conservative lookahead covers, so the
//! engine executes the exact sequential prefix until collection starts,
//! then splits. The split point is identical in both engines, so this
//! costs determinism nothing (and Amdahl very little — warm-up is a small
//! request fraction).
//!
//! The protocol was additionally validated against a Python model of this
//! exact design (sequential vs fixed-window vs adaptive on randomized
//! fabrics with zero-latency links, multi-hop relays, and zero-delay
//! self events — per-node event orders byte-identical across all three,
//! delivery-behind-horizon never observed, message accounting exact).

use super::{Component, Engine, Ev, EventQueue, IntraStats, Shared};
use crate::engine::time::Ps;
use crate::interconnect::{Dir, Partition, WeightModel};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;

/// Which conservative barrier protocol [`run_partitioned`] drives (see
/// module docs). Every mode is byte-identical to
/// [`Engine::reference_sequential`]; only wall-clock, window count and
/// exchange volume move.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BarrierMode {
    /// One lookahead per window, one message per channel per window —
    /// the PR 4/5 lockstep protocol, kept as the A/B oracle.
    FixedWindow,
    /// Horizon-driven window widening + quiet-token elision.
    #[default]
    Adaptive,
}

/// Coordinator -> worker command.
enum Cmd {
    /// Fixed-window round: drain events strictly before the window end,
    /// send one `Msg` per neighbor channel, receive one from each.
    Window(Ps),
    /// Adaptive round: first receive the pending batch on every peer
    /// slot flagged in `recv`, then drain strictly before `end`, then
    /// send only the non-empty outbound batches.
    Adaptive { end: Ps, recv: Vec<bool> },
    Stop,
}

/// One window's worth of cross-domain events for one cut-neighbor. The
/// fixed-window protocol sends exactly one `Msg` per directed neighbor
/// channel per window (`Quiet` when there is no traffic); the adaptive
/// protocol only ever sends `Events` and elides the rest.
enum Msg {
    Quiet,
    Events(Vec<Ev>),
}

/// Worker -> coordinator report: sent once at startup and after every
/// round the worker takes part in. `sent[slot]` carries the minimum
/// event time of the batch just pushed onto that peer channel (`None` =
/// nothing sent; always empty in fixed-window mode) so the coordinator
/// can account for in-flight events when it seeds the next horizon
/// relaxation.
struct Report {
    dom: usize,
    next: Option<Ps>,
    sent: Vec<Option<Ps>>,
}

type MsgTx = SyncSender<Msg>;
type MsgRx = Receiver<Msg>;
/// Full-length component table; only the owning domain's nodes are `Some`.
type CompTable = Vec<Option<Box<dyn Component>>>;

/// One event domain's runtime state, moved onto its worker thread.
struct DomainRunner {
    dom: usize,
    shared: Shared,
    comps: CompTable,
    domain_of: Arc<Vec<u32>>,
    processed: u64,
    /// Highest window end this domain has drained past. Deliveries are
    /// asserted against it: the conservative safety condition is
    /// precisely "no delivered event is behind the receiver's drained
    /// horizon".
    drained_to: Ps,
    /// Exchange accounting (summed into [`IntraStats`] at the merge).
    msgs_sent: u64,
    quiet_sent: u64,
    events_sent: u64,
}

impl DomainRunner {
    /// Drain every local event strictly before `end` in canonical key
    /// order. Handlers may schedule further local events inside the
    /// window (zero-delay self events included) — the loop picks them up.
    fn drain_window(&mut self, end: Ps) {
        while let Some(ev) = self.shared.queue.pop_if_before(end) {
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.shared.cur = ev.target;
            self.comps[ev.target]
                .as_mut()
                .expect("event targeted a foreign node")
                .handle(ev.payload, &mut self.shared);
            self.processed += 1;
        }
        self.drained_to = self.drained_to.max(end);
    }

    /// Split the outbound buffer into per-peer-slot batches.
    fn batch_outbound(&mut self, peer_slot: &[Option<usize>], n_slots: usize) -> Vec<Vec<Ev>> {
        let mut batches: Vec<Vec<Ev>> = (0..n_slots).map(|_| Vec::new()).collect();
        for ev in self.shared.take_outbound() {
            // Cross-domain events can only arise from a forward over a
            // cut link, whose far side is a cut-neighbor by construction
            // (Partition::exchange_peers).
            let slot = peer_slot[self.domain_of[ev.target] as usize]
                .expect("cross-domain event targets a non-neighbor domain");
            batches[slot].push(ev);
        }
        batches
    }
}

/// Worker thread body. Fixed-window rounds: drain, send one `Msg` to
/// every cut-neighbor, receive one from every cut-neighbor, report the
/// next local event time. Adaptive rounds: receive the flagged pending
/// batches, drain, send only non-empty batches, report next time plus
/// per-slot batch minima. Both exchanges are deadlock-free: a worker
/// sends all its messages before anyone needs to receive them, and each
/// neighbor channel carries at most one undelivered message per round
/// (capacity 2 keeps sends non-blocking even when a new batch lands
/// while the previous one is still being collected). `peer_slot` maps a
/// domain id to its slot in the parallel `out_tx` / `in_rx` vectors
/// (ascending peer-domain order).
fn worker_loop(
    mut r: DomainRunner,
    peer_slot: Vec<Option<usize>>,
    cmd_rx: Receiver<Cmd>,
    out_tx: Vec<MsgTx>,
    in_rx: Vec<MsgRx>,
    report_tx: Sender<Report>,
) -> DomainRunner {
    let report = |r: &mut DomainRunner, sent: Vec<Option<Ps>>| {
        report_tx
            .send(Report {
                dom: r.dom,
                next: r.shared.queue.next_time(),
                sent,
            })
            .expect("coordinator alive");
    };
    report(&mut r, Vec::new());
    loop {
        match cmd_rx.recv().expect("coordinator alive") {
            Cmd::Stop => break,
            Cmd::Window(end) => {
                r.drain_window(end);
                let batches = r.batch_outbound(&peer_slot, out_tx.len());
                for (slot, batch) in batches.into_iter().enumerate() {
                    r.msgs_sent += 1;
                    let msg = if batch.is_empty() {
                        r.quiet_sent += 1;
                        Msg::Quiet
                    } else {
                        r.events_sent += batch.len() as u64;
                        Msg::Events(batch)
                    };
                    out_tx[slot].send(msg).expect("peer alive");
                }
                for rx in &in_rx {
                    if let Msg::Events(evs) = rx.recv().expect("peer alive") {
                        for ev in evs {
                            r.shared.queue.push(ev);
                        }
                    }
                }
                report(&mut r, Vec::new());
            }
            Cmd::Adaptive { end, recv } => {
                for (slot, rx) in in_rx.iter().enumerate() {
                    if !recv[slot] {
                        continue;
                    }
                    match rx.recv().expect("peer alive") {
                        Msg::Events(evs) => {
                            for ev in evs {
                                // The elision-safety property: quiet-run
                                // elision (and window widening) must
                                // never have advanced this domain past a
                                // neighbor's published horizon. Always
                                // on: a violated bound here would
                                // otherwise surface as silent event
                                // reordering.
                                assert!(
                                    ev.time >= r.drained_to,
                                    "delivery behind drained horizon: {} < {}",
                                    ev.time,
                                    r.drained_to
                                );
                                r.shared.queue.push(ev);
                            }
                        }
                        Msg::Quiet => unreachable!("adaptive exchange elides quiet tokens"),
                    }
                }
                r.drain_window(end);
                let batches = r.batch_outbound(&peer_slot, out_tx.len());
                let mut sent: Vec<Option<Ps>> = vec![None; out_tx.len()];
                for (slot, batch) in batches.into_iter().enumerate() {
                    if batch.is_empty() {
                        continue;
                    }
                    r.msgs_sent += 1;
                    r.events_sent += batch.len() as u64;
                    sent[slot] = batch.iter().map(|e| e.time).min();
                    out_tx[slot].send(Msg::Events(batch)).expect("peer alive");
                }
                report(&mut r, sent);
            }
        }
    }
    r
}

/// Entry point behind [`Engine::run_partitioned`]. Runs the engine to
/// completion on up to `intra_jobs` worker threads (0 = all cores) and
/// returns the number of events processed. Falls back to the sequential
/// loop when the fabric cannot be cut or one job is requested.
pub fn run_partitioned(
    engine: &mut Engine,
    intra_jobs: usize,
    model: WeightModel,
    mode: BarrierMode,
) -> u64 {
    let jobs = if intra_jobs == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        intra_jobs
    };
    if jobs <= 1 {
        return engine.run(u64::MAX);
    }
    let part =
        Partition::compute_weighted(&engine.shared.topo, &engine.shared.routing, jobs, model);
    if part.n_domains() <= 1 {
        return engine.run(u64::MAX);
    }
    // A quiescent-restored engine (Engine::restore of a snapshot taken by
    // run_until_collecting) re-enters here exactly at the Phase A
    // boundary: collecting is already true with the epoch open, so the
    // prefix loop below no-ops and the split proceeds as if the prefix
    // had just been executed in-process. Mid-run checkpoints are NOT
    // barrier-quiescent and must continue sequentially via run().
    if engine.started {
        assert!(
            engine.restored_quiescent,
            "run_partitioned on a started engine requires a quiescent \
             (warm-up boundary) snapshot restore; mid-run checkpoints \
             resume with the sequential engine"
        );
    } else {
        // ---- Phase A: exact sequential prefix until the epoch opens.
        engine.start_components();
    }
    let mut prefix = 0u64;
    while !engine.shared.collecting {
        let Some(ev) = engine.shared.queue.pop() else { break };
        debug_assert!(ev.time >= engine.shared.now, "time went backwards");
        engine.shared.now = ev.time;
        engine.shared.cur = ev.target;
        engine.components[ev.target].handle(ev.payload, &mut engine.shared);
        prefix += 1;
    }
    let n_nodes = engine.shared.topo.n();
    engine.shared.set_origin(n_nodes);
    if engine.shared.queue.is_empty() {
        // Drained before (or exactly when) collection started.
        let now = engine.shared.now;
        engine.shared.net.end_epoch(now);
        engine.events_processed += prefix;
        return prefix;
    }

    // ---- Split: per-domain queues, components, and Shared shards.
    let ndom = part.n_domains();
    let domain_of: Arc<Vec<u32>> = Arc::new(part.domain_of.clone());
    let mut queues: Vec<EventQueue> = (0..ndom).map(|_| EventQueue::default()).collect();
    while let Some(ev) = engine.shared.queue.pop() {
        queues[domain_of[ev.target] as usize].push(ev);
    }
    let mut comp_split: Vec<CompTable> =
        (0..ndom).map(|_| (0..n_nodes).map(|_| None).collect()).collect();
    for (id, c) in engine.components.drain(..).enumerate() {
        comp_split[domain_of[id] as usize][id] = Some(c);
    }
    let mut runners: Vec<DomainRunner> = Vec::with_capacity(ndom);
    for (dom, (queue, comps)) in queues.into_iter().zip(comp_split).enumerate() {
        runners.push(DomainRunner {
            dom,
            shared: engine
                .shared
                .domain_shard(queue, dom as u32, Arc::clone(&domain_of)),
            comps,
            domain_of: Arc::clone(&domain_of),
            processed: 0,
            drained_to: 0,
            msgs_sent: 0,
            quiet_sent: 0,
            events_sent: 0,
        });
    }

    // ---- Channels: sparse neighbor wiring from the cut set, plus the
    // command/report star. Only cut-adjacent domain pairs get a channel
    // pair; a fully disconnected multi-domain fabric gets none at all.
    let peers = part.exchange_peers(&engine.shared.topo);
    // Per-domain (peer, min cut latency) edges for the adaptive horizon
    // relaxation — same order as `peers` (ESF-C013 proves the mirror).
    let hg = part.horizon_graph(&engine.shared.topo);
    debug_assert!(
        peers
            .iter()
            .zip(&hg)
            .all(|(ps, es)| ps.iter().copied().eq(es.iter().map(|&(p, _)| p))),
        "horizon graph must mirror the exchange peer lists"
    );
    let channels: usize = peers.iter().map(Vec::len).sum();
    let mut peer_slots: Vec<Vec<Option<usize>>> = (0..ndom).map(|_| vec![None; ndom]).collect();
    for (d, ps) in peers.iter().enumerate() {
        for (slot, &p) in ps.iter().enumerate() {
            peer_slots[d][p] = Some(slot);
        }
    }
    let mut out_tx: Vec<Vec<Option<MsgTx>>> =
        peers.iter().map(|ps| ps.iter().map(|_| None).collect()).collect();
    let mut in_rx: Vec<Vec<Option<MsgRx>>> =
        peers.iter().map(|ps| ps.iter().map(|_| None).collect()).collect();
    for (i, ps) in peers.iter().enumerate() {
        for (si, &j) in ps.iter().enumerate() {
            if j > i {
                let sj = peer_slots[j][i].expect("peer relation is symmetric");
                // Capacity 2 > the single undelivered message per round.
                let (tij, rij) = sync_channel(2);
                let (tji, rji) = sync_channel(2);
                out_tx[i][si] = Some(tij);
                in_rx[j][sj] = Some(rij);
                out_tx[j][sj] = Some(tji);
                in_rx[i][si] = Some(rji);
            }
        }
    }
    let (report_tx, report_rx) = channel::<Report>();
    let mut cmd_txs: Vec<SyncSender<Cmd>> = Vec::with_capacity(ndom);
    let mut cmd_rxs: Vec<Receiver<Cmd>> = Vec::with_capacity(ndom);
    for _ in 0..ndom {
        let (tx, rx) = sync_channel(1);
        cmd_txs.push(tx);
        cmd_rxs.push(rx);
    }

    // ---- Run: workers in barrier rounds, coordinator on this thread.
    let lookahead = part.lookahead;
    let mut windows = 0u64;
    let mut widened_windows = 0u64;
    let runners: Vec<DomainRunner> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(ndom);
        let mut worker_slots = peer_slots;
        let mut out_tx = out_tx;
        let mut in_rx = in_rx;
        let mut cmd_rxs = cmd_rxs;
        for r in runners.into_iter().rev() {
            let slots = worker_slots.pop().expect("slot row per domain");
            let txs: Vec<MsgTx> = out_tx
                .pop()
                .expect("tx row per domain")
                .into_iter()
                .map(|t| t.expect("every peer slot wired"))
                .collect();
            let rxs: Vec<MsgRx> = in_rx
                .pop()
                .expect("rx row per domain")
                .into_iter()
                .map(|t| t.expect("every peer slot wired"))
                .collect();
            let cmd = cmd_rxs.pop().expect("cmd channel per domain");
            let rep = report_tx.clone();
            handles.push(s.spawn(move || worker_loop(r, slots, cmd, txs, rxs, rep)));
        }
        handles.reverse(); // spawned in reverse domain order

        // Coordinator state: last reported next-event time per domain,
        // and (adaptive) the minimum event time of the batch in flight
        // on each inbound peer slot.
        let mut next: Vec<Option<Ps>> = vec![None; ndom];
        let mut inflight: Vec<Vec<Option<Ps>>> =
            peers.iter().map(|ps| vec![None; ps.len()]).collect();
        for _ in 0..ndom {
            let rep = report_rx.recv().expect("worker alive");
            next[rep.dom] = rep.next;
        }
        loop {
            // Earliest possible activity per domain: local queue or an
            // undelivered inbound batch.
            let seeds: Vec<Option<Ps>> = (0..ndom)
                .map(|d| {
                    inflight[d]
                        .iter()
                        .flatten()
                        .fold(next[d], |acc, &m| Some(acc.map_or(m, |a| a.min(m))))
                })
                .collect();
            let Some(tmin) = seeds.iter().flatten().copied().min() else {
                // All domains idle, nothing in flight: done.
                for tx in &cmd_txs {
                    tx.send(Cmd::Stop).expect("worker alive");
                }
                break;
            };
            windows += 1;
            match mode {
                BarrierMode::FixedWindow => {
                    // Saturating: a disconnected multi-domain fabric has
                    // no cut links and an unbounded Ps::MAX lookahead —
                    // the window must clamp, not wrap.
                    let end = tmin.saturating_add(lookahead);
                    for tx in &cmd_txs {
                        tx.send(Cmd::Window(end)).expect("worker alive");
                    }
                    for _ in 0..ndom {
                        let rep = report_rx.recv().expect("worker alive");
                        next[rep.dom] = rep.next;
                    }
                }
                BarrierMode::Adaptive => {
                    // Min-plus relaxation of the seeds over the horizon
                    // graph: dist[d] = earliest time d could process any
                    // event this round, including relayed ones. Positive
                    // edge weights (cut links are never zero-latency)
                    // make this a Bellman-Ford fixpoint in <= ndom
                    // passes.
                    let mut dist = seeds.clone();
                    for _ in 0..ndom {
                        let mut changed = false;
                        for d in 0..ndom {
                            for &(p, lat) in &hg[d] {
                                if let Some(dp) = dist[p] {
                                    let v = dp.saturating_add(lat);
                                    if dist[d].map_or(true, |cur| v < cur) {
                                        dist[d] = Some(v);
                                        changed = true;
                                    }
                                }
                            }
                        }
                        if !changed {
                            break;
                        }
                    }
                    // Certified inbound horizon = granted window end.
                    let classic = tmin.saturating_add(lookahead);
                    let mut widened = false;
                    let mut participants = 0usize;
                    for d in 0..ndom {
                        let horizon = hg[d]
                            .iter()
                            .filter_map(|&(p, lat)| dist[p].map(|dp| dp.saturating_add(lat)))
                            .min()
                            .unwrap_or(Ps::MAX);
                        let active = seeds[d].is_some_and(|sd| sd < horizon);
                        let pending = inflight[d].iter().any(Option::is_some);
                        if !active && !pending {
                            continue; // parked: horizon already published
                        }
                        participants += 1;
                        if active && horizon > classic {
                            widened = true;
                        }
                        let recv: Vec<bool> =
                            inflight[d].iter().map(Option::is_some).collect();
                        for slot in inflight[d].iter_mut() {
                            *slot = None;
                        }
                        cmd_txs[d]
                            .send(Cmd::Adaptive { end: horizon, recv })
                            .expect("worker alive");
                    }
                    if widened {
                        widened_windows += 1;
                    }
                    assert!(participants > 0, "adaptive barrier made no progress");
                    for _ in 0..participants {
                        let rep = report_rx.recv().expect("worker alive");
                        next[rep.dom] = rep.next;
                        for (slot, &m) in rep.sent.iter().enumerate() {
                            let Some(m) = m else { continue };
                            let p = peers[rep.dom][slot];
                            let back = peers[p]
                                .binary_search(&rep.dom)
                                .expect("peer relation is symmetric");
                            debug_assert!(
                                inflight[p][back].is_none(),
                                "neighbor channel overrun"
                            );
                            inflight[p][back] = Some(m);
                        }
                    }
                }
            }
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect()
    });

    // ---- Merge: components back in node order, owned link directions,
    // per-node counters, drop counts, exchange stats, global clock.
    let dir_owner: Vec<[u32; 2]> = engine
        .shared
        .topo
        .links
        .iter()
        .map(|l| [domain_of[l.a], domain_of[l.b]])
        .collect();
    let mut comps_back: CompTable = (0..n_nodes).map(|_| None).collect();
    let mut total = 0u64;
    let mut max_now = engine.shared.now;
    let mut stats = IntraStats {
        domains: ndom,
        windows,
        widened_windows,
        channels,
        ..IntraStats::default()
    };
    for mut r in runners {
        total += r.processed;
        max_now = max_now.max(r.shared.now);
        engine.shared.dropped += r.shared.dropped;
        stats.messages += r.msgs_sent;
        stats.quiet_messages += r.quiet_sent;
        stats.events_exchanged += r.events_sent;
        let dom = r.dom as u32;
        debug_assert_eq!(Dir::AtoB as usize, 0);
        engine
            .shared
            .net
            .adopt_owned(&r.shared.net, |link, dir| dir_owner[link][dir as usize] == dom);
        for &node in &part.domains[r.dom] {
            engine.shared.sched_seq[node] = r.shared.sched_seq[node];
            engine.shared.txn_seq[node] = r.shared.txn_seq[node];
            comps_back[node] = r.comps[node].take();
        }
    }
    // Elided tokens: channel-rounds the fixed-window protocol would have
    // filled with a message. Exactly zero in fixed-window mode, where
    // messages == windows * channels by construction.
    stats.elided_tokens = windows * channels as u64 - stats.messages;
    engine.components = comps_back
        .into_iter()
        .map(|c| c.expect("every component returns from its domain"))
        .collect();
    engine.shared.now = max_now;
    engine.shared.net.end_epoch(max_now);
    engine.events_processed += prefix + total;
    engine.intra_stats = Some(stats);
    prefix + total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Payload, Shared};
    use crate::interconnect::{LinkCfg, NodeKind, Routing, Strategy, Topology};
    use crate::proto::{NodeId, Opcode, Packet};
    use std::any::Any;

    /// Ping-pong component: every node fires requests at a deterministic
    /// subset of peers and bounces responses, recording each handled
    /// event's (time, src-key) so the processing ORDER itself can be
    /// compared between engines — stricter than comparing aggregates.
    struct Chatter {
        id: NodeId,
        n: usize,
        rounds: u64,
        log: Vec<(Ps, u64)>,
    }

    impl Component for Chatter {
        fn start(&mut self, ctx: &mut Shared) {
            ctx.after((self.id as u64 % 3) * 100, self.id, Payload::Timer(0, 0));
        }
        fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
            match payload {
                Payload::Timer(round, _) => {
                    self.log.push((ctx.now, round));
                    if round >= self.rounds {
                        return;
                    }
                    let dst = (self.id + 1 + (round as usize % (self.n - 1))) % self.n;
                    let id = ctx.txn_id();
                    let mut pkt =
                        Packet::request(id, Opcode::MemRd, self.id, dst, round, ctx.now);
                    pkt.payload_bytes = 64;
                    ctx.forward(pkt, 0);
                    // Zero-delay self event: stresses same-window re-pops.
                    ctx.after(0, self.id, Payload::Timer(round + 1, 1));
                }
                Payload::Packet(pkt) => {
                    self.log.push((ctx.now, 1_000_000 + pkt.addr));
                    if matches!(pkt.op, Opcode::MemRd) && pkt.addr % 2 == 0 {
                        let rsp = pkt.response(false);
                        ctx.forward(rsp, 50);
                    }
                }
                _ => {}
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    /// Ring of directly linked nodes — every node pair routable, cuts
    /// guaranteed for >= 2 domains.
    fn chatter_engine(n: usize, rounds: u64) -> Engine {
        let mut t = Topology::new();
        for i in 0..n {
            t.add_node(format!("n{i}"), NodeKind::Switch);
        }
        for i in 0..n {
            t.add_link(i, (i + 1) % n, LinkCfg::default());
        }
        let routing = Routing::build_bfs(&t);
        let mut e = Engine::new(Shared::new(t, routing, Strategy::Oblivious));
        for i in 0..n {
            e.register(Box::new(Chatter {
                id: i,
                n,
                rounds,
                log: Vec::new(),
            }));
        }
        e
    }

    fn logs(e: &Engine) -> Vec<Vec<(Ps, u64)>> {
        (0..e.shared.topo.n())
            .map(|i| e.component::<Chatter>(i).unwrap().log.clone())
            .collect()
    }

    #[test]
    fn partitioned_matches_sequential_event_orders_exactly() {
        for model in [WeightModel::Traffic, WeightModel::NodeCount] {
            for mode in [BarrierMode::Adaptive, BarrierMode::FixedWindow] {
                for jobs in [2, 3, 4, 8] {
                    let mut seq = chatter_engine(12, 40);
                    let n_seq = seq.reference_sequential();
                    let mut par = chatter_engine(12, 40);
                    let n_par = par.run_partitioned_opts(jobs, model, mode);
                    assert_eq!(
                        n_seq, n_par,
                        "event counts diverged at jobs={jobs} {model:?} {mode:?}"
                    );
                    assert_eq!(
                        logs(&seq),
                        logs(&par),
                        "per-node event order diverged at jobs={jobs} {model:?} {mode:?}"
                    );
                    assert_eq!(seq.shared.now, par.shared.now);
                    assert_eq!(seq.shared.dropped, par.shared.dropped);
                    for l in 0..seq.shared.topo.links.len() {
                        assert_eq!(
                            seq.shared.net.payload_bytes(l),
                            par.shared.net.payload_bytes(l),
                            "link {l} payload diverged at jobs={jobs}"
                        );
                        assert_eq!(
                            seq.shared.net.bus_utility(l).to_bits(),
                            par.shared.net.bus_utility(l).to_bits(),
                            "link {l} utility diverged at jobs={jobs}"
                        );
                    }
                }
            }
        }
    }

    /// The sparse exchange must open strictly fewer channels than the
    /// all-to-all mesh whenever the cut graph is not complete, and the
    /// accounting must be self-consistent: every channel-round either
    /// carried a message or was elided, quiet tokens a subset of
    /// messages. On a ring cut into 4 arcs every domain has exactly two
    /// cut-neighbors.
    #[test]
    fn sparse_exchange_opens_neighbor_channels_only() {
        let mut e = chatter_engine(12, 40);
        let events = e.run_partitioned(4);
        assert!(events > 0);
        let s = e.intra_stats.expect("partitioned run records stats");
        assert_eq!(s.domains, 4);
        // Ring arcs: 2 neighbors per domain -> 8 directed channels, vs
        // 4 * 3 = 12 all-to-all.
        assert_eq!(s.channels, 8);
        assert!(s.channels < s.domains * (s.domains - 1));
        assert!(s.windows > 0);
        assert_eq!(s.messages + s.elided_tokens, s.windows * s.channels as u64);
        assert_eq!(s.quiet_messages, 0, "adaptive mode elides quiet tokens");
        assert!(s.events_exchanged > 0, "chatter must cross domains");
        // Sequential runs leave no stats behind.
        let mut seq = chatter_engine(12, 40);
        seq.reference_sequential();
        assert!(seq.intra_stats.is_none());
        let mut one = chatter_engine(12, 40);
        one.run_partitioned(1);
        assert!(one.intra_stats.is_none(), "fallback path must not record");
    }

    /// Fixed-window mode keeps the PR 5 accounting exactly (one message
    /// per channel per window); adaptive mode must beat it on both
    /// windows and messages while exchanging the same events.
    #[test]
    fn adaptive_mode_elides_tokens_and_widens_windows() {
        let mut fixed = chatter_engine(12, 40);
        fixed.run_partitioned_opts(4, WeightModel::Traffic, BarrierMode::FixedWindow);
        let f = fixed.intra_stats.expect("stats");
        assert_eq!(f.messages, f.windows * f.channels as u64);
        assert_eq!(f.elided_tokens, 0);
        assert_eq!(f.widened_windows, 0);
        assert!(f.quiet_messages <= f.messages);

        let mut adaptive = chatter_engine(12, 40);
        adaptive.run_partitioned_opts(4, WeightModel::Traffic, BarrierMode::Adaptive);
        let a = adaptive.intra_stats.expect("stats");
        assert_eq!(a.channels, f.channels);
        assert_eq!(a.events_exchanged, f.events_exchanged);
        assert!(a.windows <= f.windows, "adaptive needed more rounds");
        assert!(a.messages < f.messages, "no message reduction");
        assert!(a.widened_windows > 0, "no window ever widened");
        assert!(a.elided_tokens > 0, "no token ever elided");
        assert_eq!(a.messages + a.elided_tokens, a.windows * a.channels as u64);
        assert_eq!(logs(&fixed), logs(&adaptive));
    }

    #[test]
    fn single_job_partitioned_is_the_sequential_path() {
        let mut a = chatter_engine(6, 10);
        let na = a.run(u64::MAX);
        let mut b = chatter_engine(6, 10);
        let nb = b.run_partitioned(1);
        assert_eq!(na, nb);
        assert_eq!(logs(&a), logs(&b));
    }

    #[test]
    fn empty_engine_partitioned_run_terminates() {
        // No components schedule anything after start when rounds == 0
        // budget is still >= 1 event per node (the initial timer).
        let mut e = chatter_engine(4, 0);
        let n = e.run_partitioned(4);
        assert!(n >= 4);
        assert!(e.shared.queue.is_empty());
    }

    /// Two disconnected chatter rings: the partitioner splits them into
    /// domains with an empty cut set (lookahead Ps::MAX, zero channels);
    /// the saturating window must drain everything in one shot and still
    /// match the sequential order exactly.
    #[test]
    fn disconnected_fabric_runs_with_unbounded_windows() {
        let build = || {
            let mut t = Topology::new();
            for i in 0..8 {
                t.add_node(format!("n{i}"), NodeKind::Switch);
            }
            for c in 0..2usize {
                let base = c * 4;
                for i in 0..4 {
                    t.add_link(base + i, base + (i + 1) % 4, LinkCfg::default());
                }
            }
            let routing = Routing::build_bfs(&t);
            let mut e = Engine::new(Shared::new(t, routing, Strategy::Oblivious));
            for i in 0..8 {
                e.register(Box::new(Chatter {
                    id: i,
                    n: 8,
                    rounds: 12,
                    log: Vec::new(),
                }));
            }
            e
        };
        // Chatter picks dst in 0..8, so cross-component packets exist —
        // they are unroutable and dropped, identically in both engines.
        let mut seq = build();
        let n_seq = seq.reference_sequential();
        for mode in [BarrierMode::Adaptive, BarrierMode::FixedWindow] {
            for jobs in [2, 4] {
                let mut par = build();
                let n_par = par.run_partitioned_opts(jobs, WeightModel::Traffic, mode);
                assert_eq!(
                    n_seq, n_par,
                    "disconnected fabric diverged at jobs={jobs} {mode:?}"
                );
                assert_eq!(logs(&seq), logs(&par));
                assert_eq!(seq.shared.dropped, par.shared.dropped);
                if let Some(s) = par.intra_stats {
                    // Both rings are internally connected, so a 2-domain
                    // cut may have zero channels; assert the accounting
                    // holds either way.
                    assert_eq!(
                        s.messages + s.elided_tokens,
                        s.windows * s.channels as u64
                    );
                }
            }
        }
    }
}
