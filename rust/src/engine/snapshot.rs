//! Versioned binary engine snapshots: the full resumable state of a
//! sequential engine — event queue contents with their exact
//! `(time, src, seq)` keys, `NetState`, per-node schedule/txn counters,
//! component state (RNG registers included), epoch/warm-up bookkeeping —
//! behind a digest-verified header.
//!
//! Contract (pinned by `tests/checkpoint.rs`): restore-then-run is
//! byte-identical to straight-through — same golden digests, same
//! `esf run --json` dump. Two snapshot points exist:
//!
//! * **Quiescent** ([`Engine::run_until_collecting`], flag bit 0 set):
//!   taken exactly at the warm-up→collection flip, the same
//!   barrier-quiescent boundary `parallel::run_partitioned` reaches at
//!   the end of its sequential Phase A. A quiescent restore may continue
//!   under `run()` **or** `run_partitioned()` — this is what warm-start
//!   prefix sharing forks from.
//! * **Mid-run** ([`Engine::run_until`] stepping, flag clear): epoch
//!   closed at the snapshot horizon; continuation is sequential-only
//!   (`run_partitioned` rejects it — the barrier protocol assumes it
//!   owns the run from the collection flip onward).
//!
//! File layout (all little-endian, see `util::snap`):
//!
//! ```text
//! magic      [u8; 8]   "ESFSNAP\0"
//! version    u32        SNAP_VERSION
//! flags      u32        bit 0 = quiescent
//! cfg_fp     u64        SystemCfg::fingerprint() of the snapshotted system
//! prefix_fp  u64        SystemCfg::prefix_fingerprint() (warm-up prefix key)
//! prefix     str        canonical prefix-projected config JSON
//! body       bytes      engine state (opaque outside this module)
//! digest     u64        FNV-1a 64 over every preceding byte
//! ```
//!
//! Header validity, digest verification, and fork compatibility are
//! re-proved by `esf check` rule ESF-C014 before any restore.

use super::{Engine, Ev, Payload};
use crate::proto::{Opcode, Packet};
use crate::util::fnv1a64;
use crate::util::snap::{SnapReader, SnapWriter};

pub const SNAP_MAGIC: [u8; 8] = *b"ESFSNAP\0";
pub const SNAP_VERSION: u32 = 1;
const FLAG_QUIESCENT: u32 = 1;

/// Identity fields a snapshot carries ahead of its body.
#[derive(Clone, Debug)]
pub struct SnapMeta {
    /// `SystemCfg::fingerprint()` of the exact snapshotted config.
    pub cfg_fingerprint: u64,
    /// `SystemCfg::prefix_fingerprint()` — the warm-up prefix key a
    /// forking config must share.
    pub prefix_fingerprint: u64,
    /// Canonical prefix-projected config JSON (human-auditable tiebreak
    /// for the 64-bit prefix fingerprint).
    pub prefix_canon: String,
    /// Taken at the barrier-quiescent collection flip (fork-safe)?
    pub quiescent: bool,
}

/// Parsed snapshot header (body not yet decoded).
#[derive(Clone, Debug)]
pub struct SnapHeader {
    pub version: u32,
    pub quiescent: bool,
    pub cfg_fingerprint: u64,
    pub prefix_fingerprint: u64,
    pub prefix_canon: String,
}

/// Structured header/digest failure — each variant maps onto one
/// ESF-C014 locus (`SnapError::locus`).
#[derive(Clone, Debug)]
pub enum SnapError {
    Magic(String),
    Version(String),
    Digest(String),
    Body(String),
}

impl SnapError {
    pub fn locus(&self) -> &'static str {
        match self {
            SnapError::Magic(_) => "snapshot.magic",
            SnapError::Version(_) => "snapshot.version",
            SnapError::Digest(_) => "snapshot.digest",
            SnapError::Body(_) => "snapshot.body",
        }
    }

    pub fn message(&self) -> &str {
        match self {
            SnapError::Magic(m)
            | SnapError::Version(m)
            | SnapError::Digest(m)
            | SnapError::Body(m) => m,
        }
    }
}

impl std::fmt::Display for SnapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.locus(), self.message())
    }
}

/// Validate magic + version + trailing digest, then split header from
/// body. Every byte of the file is covered: the digest spans everything
/// before the trailer, so truncation and bit-flips anywhere surface here.
pub fn parse(bytes: &[u8]) -> Result<(SnapHeader, &[u8]), SnapError> {
    if bytes.len() < SNAP_MAGIC.len() || bytes[..SNAP_MAGIC.len()] != SNAP_MAGIC {
        return Err(SnapError::Magic(
            "not an ESF snapshot (bad magic)".to_string(),
        ));
    }
    let mut r = SnapReader::new(&bytes[SNAP_MAGIC.len()..]);
    let version = r.u32().map_err(SnapError::Digest)?;
    if version != SNAP_VERSION {
        return Err(SnapError::Version(format!(
            "unsupported snapshot version {version} (this build reads version {SNAP_VERSION})"
        )));
    }
    if bytes.len() < SNAP_MAGIC.len() + 4 + 8 {
        return Err(SnapError::Digest("truncated before digest".to_string()));
    }
    let trailer = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let actual = fnv1a64(&bytes[..bytes.len() - 8]);
    if trailer != actual {
        return Err(SnapError::Digest(format!(
            "digest mismatch: file says {trailer:#018x}, content hashes to {actual:#018x} \
             (truncated or corrupt snapshot)"
        )));
    }
    // Digest verified: the remaining fields decode unless the writer was
    // broken, but stay defensive — a length prefix could still overrun.
    let flags = r.u32().map_err(SnapError::Body)?;
    let cfg_fingerprint = r.u64().map_err(SnapError::Body)?;
    let prefix_fingerprint = r.u64().map_err(SnapError::Body)?;
    let prefix_canon = r.str().map_err(SnapError::Body)?;
    let body = r.bytes().map_err(SnapError::Body)?;
    if r.remaining() != 8 {
        return Err(SnapError::Body(format!(
            "{} bytes between body and digest trailer",
            r.remaining().saturating_sub(8)
        )));
    }
    Ok((
        SnapHeader {
            version,
            quiescent: flags & FLAG_QUIESCENT != 0,
            cfg_fingerprint,
            prefix_fingerprint,
            prefix_canon,
        },
        body,
    ))
}

/// Parse just the header of a snapshot file (ESF-C014's view).
pub fn header(bytes: &[u8]) -> Result<SnapHeader, SnapError> {
    parse(bytes).map(|(h, _)| h)
}

fn write_opcode(w: &mut SnapWriter, op: Opcode) {
    match op {
        Opcode::MemRd => w.u8(0),
        Opcode::MemWr => w.u8(1),
        Opcode::MemRdData => w.u8(2),
        Opcode::MemWrCmp => w.u8(3),
        Opcode::BISnp { len } => {
            w.u8(4);
            w.u8(len);
        }
        Opcode::BIRsp { dirty } => {
            w.u8(5);
            w.bool(dirty);
        }
        Opcode::IoCfg => w.u8(6),
    }
}

fn read_opcode(r: &mut SnapReader<'_>) -> Result<Opcode, String> {
    Ok(match r.u8()? {
        0 => Opcode::MemRd,
        1 => Opcode::MemWr,
        2 => Opcode::MemRdData,
        3 => Opcode::MemWrCmp,
        4 => Opcode::BISnp { len: r.u8()? },
        5 => Opcode::BIRsp { dirty: r.bool()? },
        6 => Opcode::IoCfg,
        t => return Err(format!("invalid opcode tag {t}")),
    })
}

pub(crate) fn write_packet(w: &mut SnapWriter, p: &Packet) {
    w.u64(p.id);
    write_opcode(w, p.op);
    w.usize(p.src);
    w.usize(p.dst);
    w.u64(p.addr);
    w.u64(p.payload_bytes);
    w.u64(p.issued_at);
    w.usize(p.at);
    w.bool(p.coherent);
    w.bool(p.posted);
    w.u64(p.breakdown.queue_ps);
    w.u64(p.breakdown.switch_ps);
    w.u64(p.breakdown.bus_ps);
    w.u64(p.breakdown.device_ps);
    w.u32(p.breakdown.hops);
}

pub(crate) fn read_packet(r: &mut SnapReader<'_>) -> Result<Packet, String> {
    let mut p = Packet {
        id: r.u64()?,
        op: read_opcode(r)?,
        src: r.usize()?,
        dst: r.usize()?,
        addr: r.u64()?,
        payload_bytes: r.u64()?,
        issued_at: r.u64()?,
        at: r.usize()?,
        coherent: r.bool()?,
        posted: r.bool()?,
        breakdown: Default::default(),
    };
    p.breakdown.queue_ps = r.u64()?;
    p.breakdown.switch_ps = r.u64()?;
    p.breakdown.bus_ps = r.u64()?;
    p.breakdown.device_ps = r.u64()?;
    p.breakdown.hops = r.u32()?;
    Ok(p)
}

pub(crate) fn write_ev(w: &mut SnapWriter, ev: &Ev) {
    w.u64(ev.time);
    w.u32(ev.src);
    w.u64(ev.seq);
    w.usize(ev.target);
    match &ev.payload {
        Payload::Packet(p) => {
            w.u8(0);
            write_packet(w, p);
        }
        Payload::IssueTick => w.u8(1),
        Payload::Timer(a, b) => {
            w.u8(2);
            w.u64(*a);
            w.u64(*b);
        }
    }
}

pub(crate) fn read_ev(r: &mut SnapReader<'_>) -> Result<Ev, String> {
    let time = r.u64()?;
    let src = r.u32()?;
    let seq = r.u64()?;
    let target = r.usize()?;
    let payload = match r.u8()? {
        0 => Payload::Packet(Box::new(read_packet(r)?)),
        1 => Payload::IssueTick,
        2 => Payload::Timer(r.u64()?, r.u64()?),
        t => return Err(format!("invalid payload tag {t}")),
    };
    Ok(Ev {
        time,
        src,
        seq,
        target,
        payload,
    })
}

impl Engine {
    /// Serialize the full resumable state. `&mut self` because the event
    /// queue is drained and re-pushed — the canonical `(time, src, seq)`
    /// total order makes that a no-op for pop order (the property the
    /// ladder/heap A/B suite pins), so a snapshotted engine continues
    /// exactly as if never snapshotted.
    pub fn snapshot(&mut self, meta: &SnapMeta) -> Vec<u8> {
        let mut buf = Vec::new();
        self.snapshot_into(&mut buf, meta);
        buf
    }

    /// [`Engine::snapshot`] into a caller-owned buffer: the buffer is
    /// cleared but keeps its capacity, so periodic checkpointing (and
    /// anything else capturing repeatedly) allocates once and then
    /// reuses the same backing storage on every capture.
    pub fn snapshot_into(&mut self, buf: &mut Vec<u8>, meta: &SnapMeta) {
        assert!(
            self.shared.part.is_none(),
            "snapshot of a partitioned domain shard (snapshot the merged engine)"
        );
        let mut w = SnapWriter::reuse(std::mem::take(buf));
        w.raw(&SNAP_MAGIC);
        w.u32(SNAP_VERSION);
        w.u32(if meta.quiescent { FLAG_QUIESCENT } else { 0 });
        w.u64(meta.cfg_fingerprint);
        w.u64(meta.prefix_fingerprint);
        w.str(&meta.prefix_canon);
        let body = self.snapshot_body();
        w.bytes(&body);
        let digest = fnv1a64(w.as_slice());
        w.u64(digest);
        *buf = w.into_bytes();
    }

    fn snapshot_body(&mut self) -> Vec<u8> {
        let mut w = SnapWriter::new();
        let s = &mut self.shared;
        w.u64(s.now);
        w.usize(s.warmups_pending);
        w.bool(s.collecting);
        w.usize(s.cur);
        w.u64(s.dropped);
        w.usize(s.sched_seq.len());
        for &v in &s.sched_seq {
            w.u64(v);
        }
        for &v in &s.txn_seq {
            w.u64(v);
        }
        w.u64(s.queue.next_seq);
        let mut evs = Vec::with_capacity(s.queue.len());
        while let Some(ev) = s.queue.pop() {
            evs.push(ev);
        }
        w.usize(evs.len());
        for ev in &evs {
            write_ev(&mut w, ev);
        }
        for ev in evs {
            s.queue.push(ev);
        }
        s.net.snapshot(&mut w);
        w.bool(self.started);
        w.u64(self.events_processed);
        w.usize(self.components.len());
        for c in &self.components {
            c.snapshot(&mut w);
        }
        w.into_bytes()
    }

    /// Rebuild a snapshot onto a freshly built engine of the same config
    /// (components registered, never run). Verifies magic/version/digest
    /// (ESF-C014 re-proves the same plus fork compatibility with loci);
    /// returns the parsed header on success. After a successful restore
    /// the engine continues with [`Engine::run`], or — when the header's
    /// quiescent flag is set — [`Engine::run_partitioned`].
    pub fn restore(&mut self, bytes: &[u8]) -> Result<SnapHeader, String> {
        let (hdr, body) = parse(bytes).map_err(|e| e.to_string())?;
        if self.started {
            return Err("restore target must be a freshly built engine".to_string());
        }
        if !self.shared.queue.is_empty() {
            return Err("restore target already has scheduled events".to_string());
        }
        let mut r = SnapReader::new(body);
        let s = &mut self.shared;
        s.now = r.u64()?;
        s.warmups_pending = r.usize()?;
        s.collecting = r.bool()?;
        s.cur = r.usize()?;
        s.dropped = r.u64()?;
        let n_ctr = r.usize()?;
        if n_ctr != s.sched_seq.len() {
            return Err(format!(
                "snapshot has {n_ctr} node counters, fabric has {}",
                s.sched_seq.len()
            ));
        }
        for v in s.sched_seq.iter_mut() {
            *v = r.u64()?;
        }
        for v in s.txn_seq.iter_mut() {
            *v = r.u64()?;
        }
        s.queue.next_seq = r.u64()?;
        let n_ev = r.usize()?;
        for _ in 0..n_ev {
            let ev = read_ev(&mut r)?;
            if ev.target >= s.topo.n() {
                return Err(format!("event targets node {} outside fabric", ev.target));
            }
            s.queue.push(ev);
        }
        s.net.restore(&mut r)?;
        let started = r.bool()?;
        if !started {
            return Err("snapshot of a never-started engine".to_string());
        }
        self.events_processed = r.u64()?;
        let n_comp = r.usize()?;
        if n_comp != self.components.len() {
            return Err(format!(
                "snapshot has {n_comp} components, engine has {}",
                self.components.len()
            ));
        }
        for c in self.components.iter_mut() {
            c.restore(&mut r)?;
        }
        r.expect_eof()?;
        self.started = true;
        self.restored_quiescent = hdr.quiescent;
        Ok(hdr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> SnapMeta {
        SnapMeta {
            cfg_fingerprint: 0x1111,
            prefix_fingerprint: 0x2222,
            prefix_canon: "{}".to_string(),
            quiescent: true,
        }
    }

    fn fake_snapshot() -> Vec<u8> {
        // Header-only file with an empty body: enough to exercise the
        // parse/digest layer without building an engine.
        let m = meta();
        let mut w = SnapWriter::new();
        w.raw(&SNAP_MAGIC);
        w.u32(SNAP_VERSION);
        w.u32(FLAG_QUIESCENT);
        w.u64(m.cfg_fingerprint);
        w.u64(m.prefix_fingerprint);
        w.str(&m.prefix_canon);
        w.bytes(&[]);
        let digest = fnv1a64(w.as_slice());
        w.u64(digest);
        w.into_bytes()
    }

    #[test]
    fn header_round_trips() {
        let bytes = fake_snapshot();
        let h = header(&bytes).unwrap();
        assert_eq!(h.version, SNAP_VERSION);
        assert!(h.quiescent);
        assert_eq!(h.cfg_fingerprint, 0x1111);
        assert_eq!(h.prefix_fingerprint, 0x2222);
        assert_eq!(h.prefix_canon, "{}");
    }

    #[test]
    fn bad_magic_is_a_magic_error() {
        let mut bytes = fake_snapshot();
        bytes[0] ^= 0xFF;
        let err = header(&bytes).unwrap_err();
        assert_eq!(err.locus(), "snapshot.magic");
    }

    #[test]
    fn version_bump_is_a_version_error() {
        let mut bytes = fake_snapshot();
        bytes[8] = bytes[8].wrapping_add(1); // version u32 low byte: 1 -> 2
        let err = header(&bytes).unwrap_err();
        assert_eq!(err.locus(), "snapshot.version");
        assert!(err.message().contains("unsupported snapshot version"));
    }

    #[test]
    fn bit_flip_and_truncation_are_digest_errors() {
        let good = fake_snapshot();
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        assert_eq!(header(&flipped).unwrap_err().locus(), "snapshot.digest");

        let mut short = good;
        short.truncate(short.len() - 3);
        assert_eq!(header(&short).unwrap_err().locus(), "snapshot.digest");
    }
}
