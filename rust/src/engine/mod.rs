//! Discrete-event simulation core.
//!
//! Deterministic: events are totally ordered by the canonical key
//! `(time, src, seq)` where `src` is the node whose handler scheduled the
//! event and `seq` is that node's private schedule counter. The key is a
//! pure function of the scheduling node's own execution history — nothing
//! about *global* interleaving leaks into it — which is what lets the
//! partitioned engine (`parallel.rs`) process independent event domains on
//! worker threads and still produce output byte-identical to the
//! sequential loop: each domain pops its own events in the same canonical
//! order the sequential engine would have handed them out. Components
//! never hold references to each other — all interaction flows through
//! scheduled events plus the passive shared state (`Shared`: link states,
//! routing tables, epoch control), which is what lets one `&mut` context
//! serve every handler, and per-domain `Shared` shards serve the
//! partitioned run.
//!
//! Scheduling uses a ladder (calendar) queue — O(1) amortized per event
//! instead of the seed's `BinaryHeap` O(log n) sift — while preserving the
//! exact key order, so outputs stay byte-identical (see EXPERIMENTS.md
//! §Hot-path and `tests/golden.rs`).

pub mod parallel;
pub mod snapshot;
pub mod time;

use crate::interconnect::{dir_of, NetState, Routing, Strategy, Topology};
use crate::proto::{NodeId, Packet};
use std::any::Any;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;
use time::Ps;

/// Event payloads delivered to components.
#[derive(Clone, Debug)]
pub enum Payload {
    /// A transaction-layer message arriving at this node. Boxed: heap
    /// entries stay small, cutting sift traffic in the event queue (see
    /// EXPERIMENTS.md §Perf).
    Packet(Box<Packet>),
    /// Requester self-tick: try to issue the next request.
    IssueTick,
    /// Generic component-defined timer (tag, data).
    Timer(u64, u64),
}

/// A pending event: totally ordered by the canonical `(time, src, seq)`
/// key. `src` is the scheduling node (`u32::MAX` for events scheduled
/// through the raw [`EventQueue::schedule`] compatibility API used by
/// queue-level tests and benches); `seq` is per-`src` monotonically
/// increasing, so `(src, seq)` is globally unique and the key is a total
/// order that both the sequential and the partitioned engine compute
/// identically.
#[derive(Debug)]
pub struct Ev {
    pub time: Ps,
    pub src: u32,
    pub seq: u64,
    pub target: NodeId,
    pub payload: Payload,
}

impl Ev {
    #[inline]
    pub fn key(&self) -> (Ps, u32, u64) {
        (self.time, self.src, self.seq)
    }
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed compare: earliest key first.
        other.key().cmp(&self.key())
    }
}

/// Upper bound on buckets per window; each rebuild sizes the window to
/// roughly one bucket per pending event within this cap.
const MAX_BUCKETS: usize = 4096;

/// Ladder (calendar) queue: the near future lives in a sorted `front`
/// vector popped from the back in O(1); the mid future is bucketed by
/// time; the far future sits in an unsorted overflow tail that is
/// redistributed into a fresh bucket window once the current one drains.
/// Amortized O(1) per event vs the binary heap's O(log n) sift, and the
/// `(time, src, seq)` total order is preserved exactly: buckets partition
/// the timeline (front < `front_end` <= buckets < `win_end` <= overflow),
/// and each bucket is sorted by the full key before it is drained.
#[derive(Debug)]
struct Ladder {
    /// Events with `time < front_end`, sorted descending by key so the
    /// globally next event pops from the back.
    front: Vec<Ev>,
    front_end: Ps,
    /// Bucket `i` holds `[win_start + i*width, win_start + (i+1)*width)`,
    /// unsorted. Only indices `cur..` are live.
    buckets: Vec<Vec<Ev>>,
    bucketed: usize,
    cur: usize,
    win_start: Ps,
    win_end: Ps,
    width: Ps,
    /// Far-future tail (`time >= win_end`), unsorted.
    overflow: Vec<Ev>,
}

impl Ladder {
    fn new() -> Ladder {
        Ladder {
            front: Vec::new(),
            front_end: 0,
            buckets: Vec::new(),
            bucketed: 0,
            cur: 0,
            win_start: 0,
            win_end: 0,
            width: 1,
            overflow: Vec::new(),
        }
    }

    fn schedule(&mut self, ev: Ev) {
        if ev.time < self.front_end {
            // Active region (includes scheduling at the current time):
            // binary insert keeps `front` sorted. The memmove is short in
            // practice — only later-key ties and the same narrow bucket
            // span sit behind the insertion point.
            let key = ev.key();
            let pos = self.front.partition_point(|e| e.key() > key);
            self.front.insert(pos, ev);
        } else if ev.time < self.win_end {
            let idx = ((ev.time - self.win_start) / self.width) as usize;
            debug_assert!(idx >= self.cur && idx < self.buckets.len());
            self.buckets[idx].push(ev);
            self.bucketed += 1;
        } else {
            self.overflow.push(ev);
        }
    }

    fn pop(&mut self) -> Option<Ev> {
        loop {
            if let Some(ev) = self.front.pop() {
                return Some(ev);
            }
            if self.bucketed > 0 {
                // Promote the next non-empty bucket to the front region.
                while self.cur < self.buckets.len() {
                    let i = self.cur;
                    self.cur += 1;
                    self.front_end = self.front_end.saturating_add(self.width);
                    if !self.buckets[i].is_empty() {
                        std::mem::swap(&mut self.front, &mut self.buckets[i]);
                        self.bucketed -= self.front.len();
                        self.front.sort_unstable_by(|a, b| b.key().cmp(&a.key()));
                        break;
                    }
                }
                continue;
            }
            // Window exhausted: rebuild from the overflow tail or report
            // empty. Jump `front_end` so later schedules keep partitioning
            // consistently.
            self.cur = self.buckets.len();
            self.front_end = self.win_end;
            if self.overflow.is_empty() {
                return None;
            }
            self.rebuild();
        }
    }

    /// Redistribute the overflow tail into a fresh bucket window sized to
    /// ~1 event per bucket, so empty-bucket skipping stays O(1) amortized.
    fn rebuild(&mut self) {
        debug_assert!(self.front.is_empty() && self.bucketed == 0);
        let evs = std::mem::take(&mut self.overflow);
        let mut lo = Ps::MAX;
        let mut hi = 0;
        for ev in &evs {
            lo = lo.min(ev.time);
            hi = hi.max(ev.time);
        }
        let nb = evs.len().clamp(1, MAX_BUCKETS).next_power_of_two();
        if self.buckets.len() < nb {
            self.buckets.resize_with(nb, Vec::new);
        }
        self.width = (hi - lo) / nb as Ps + 1;
        self.win_start = lo;
        self.win_end = lo.saturating_add(self.width.saturating_mul(nb as Ps));
        self.cur = 0;
        self.front_end = lo;
        self.bucketed = evs.len();
        for ev in evs {
            let idx = ((ev.time - lo) / self.width) as usize;
            self.buckets[idx].push(ev);
        }
    }
}

#[derive(Debug)]
enum QueueImp {
    Ladder(Ladder),
    Heap(BinaryHeap<Ev>),
}

/// Priority queue of pending events.
///
/// The default implementation is the ladder queue above. The seed's
/// `BinaryHeap` implementation is kept behind [`EventQueue::reference_heap`]
/// as the reference semantics: both order events by exactly the same
/// canonical key, which the golden-determinism test (`tests/golden.rs`)
/// and the queue property test below assert.
#[derive(Debug)]
pub struct EventQueue {
    imp: QueueImp,
    next_seq: u64,
    len: usize,
}

impl Default for EventQueue {
    fn default() -> EventQueue {
        EventQueue {
            imp: QueueImp::Ladder(Ladder::new()),
            next_seq: 0,
            len: 0,
        }
    }
}

impl EventQueue {
    /// The seed's binary-heap scheduler, kept as the reference ordering
    /// for A/B determinism tests and before/after benchmarks.
    pub fn reference_heap() -> EventQueue {
        EventQueue {
            imp: QueueImp::Heap(BinaryHeap::new()),
            next_seq: 0,
            len: 0,
        }
    }

    /// Insert a fully keyed event. The engine's scheduling paths
    /// ([`Shared::after`] etc.) build keys from the scheduling node's
    /// counters; the partitioned runtime re-inserts exchanged events with
    /// the keys they were born with.
    pub fn push(&mut self, ev: Ev) {
        self.len += 1;
        match &mut self.imp {
            QueueImp::Ladder(l) => l.schedule(ev),
            QueueImp::Heap(h) => h.push(ev),
        }
    }

    /// Compatibility scheduling for queue-level tests and benches: events
    /// get `src = u32::MAX` and a queue-global sequence number, so ties
    /// pop in FIFO schedule order exactly like the seed's `(time, seq)`
    /// contract.
    pub fn schedule(&mut self, time: Ps, target: NodeId, payload: Payload) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.push(Ev {
            time,
            src: u32::MAX,
            seq,
            target,
            payload,
        });
    }

    pub fn pop(&mut self) -> Option<Ev> {
        let ev = match &mut self.imp {
            QueueImp::Ladder(l) => l.pop(),
            QueueImp::Heap(h) => h.pop(),
        };
        if ev.is_some() {
            self.len -= 1;
        }
        ev
    }

    /// Pop the globally next event only if it is strictly before `bound`
    /// — the partitioned engine's window drain. A popped-but-too-late
    /// event is re-inserted, which preserves the key order exactly.
    pub fn pop_if_before(&mut self, bound: Ps) -> Option<Ev> {
        let ev = self.pop()?;
        if ev.time < bound {
            Some(ev)
        } else {
            self.push(ev);
            None
        }
    }

    /// Timestamp of the globally next event (used by the partitioned
    /// barrier to compute the next window).
    pub fn next_time(&mut self) -> Option<Ps> {
        let ev = self.pop()?;
        let t = ev.time;
        self.push(ev);
        Some(t)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Maximum per-node transaction count (txn ids pack `(node, count)`).
const TXN_NODE_SHIFT: u32 = 40;

/// Per-domain partitioning context: which domain this `Shared` shard
/// drives and where each node lives. Events targeting foreign nodes are
/// diverted into `outbound` and exchanged at the next barrier.
struct PartCtx {
    me: u32,
    domain_of: Arc<Vec<u32>>,
    outbound: Vec<Ev>,
}

/// Shared simulation state handed to every event handler.
///
/// In a partitioned run every domain owns a `Shared` shard: its own event
/// queue, its own `NetState` clone (only the link directions whose sender
/// lives in the domain are ever touched — see `parallel.rs`), and the
/// per-node schedule/transaction counters of its own nodes. Topology and
/// routing are immutable and cloned per shard.
pub struct Shared {
    pub now: Ps,
    pub queue: EventQueue,
    pub topo: Topology,
    pub routing: Routing,
    pub net: NetState,
    pub strategy: Strategy,
    /// Requesters still in their warm-up phase; when it reaches zero the
    /// measurement epoch starts (stats reset, collection begins).
    warmups_pending: usize,
    pub collecting: bool,
    /// Node whose handler is currently executing — the `src` of every
    /// key and txn id it mints. Slot `topo.n()` is the external-injection
    /// origin (CLI/gem5-wrapper paths).
    cur: NodeId,
    /// Per-node schedule counters (the `seq` key component).
    sched_seq: Vec<u64>,
    /// Per-node transaction counters (txn id = `(node+1) << 40 | count`,
    /// location-independent so sequential and partitioned runs mint
    /// identical ids in identical order).
    txn_seq: Vec<u64>,
    /// Count of dropped packets (no route) — failure-injection visibility.
    pub dropped: u64,
    part: Option<PartCtx>,
}

impl Shared {
    pub fn new(topo: Topology, routing: Routing, strategy: Strategy) -> Shared {
        let net = NetState::for_topology(&topo);
        let n = topo.n();
        Shared {
            now: 0,
            queue: EventQueue::default(),
            topo,
            routing,
            net,
            strategy,
            warmups_pending: 0,
            collecting: false,
            cur: n,
            sched_seq: vec![0; n + 1],
            txn_seq: vec![0; n + 1],
            dropped: 0,
            part: None,
        }
    }

    /// Set the origin node for subsequently minted keys and txn ids. The
    /// engine does this before every `start()`/`handle()`; external
    /// injectors (the gem5-style wrapper) must call it before scheduling
    /// into the engine from outside a handler.
    pub fn set_origin(&mut self, node: NodeId) {
        // Always-on (not debug_assert): an out-of-range origin would index
        // past the seq/txn counters and, worse, mint colliding ids.
        assert!(
            node <= self.topo.n(),
            "set_origin: node {node} out of range (fabric has {} nodes + 1 external slot)",
            self.topo.n()
        );
        self.cur = node;
    }

    /// Mint a transaction id for the current origin node. Ids pack
    /// `(node+1, per-node count)` so they are unique and — unlike a global
    /// counter — independent of cross-node event interleaving, which keeps
    /// them identical between the sequential and partitioned engines.
    pub fn txn_id(&mut self) -> u64 {
        let k = self.txn_seq[self.cur];
        self.txn_seq[self.cur] += 1;
        // Always-on: a counter past 2^40 would silently alias another
        // node's namespace in release builds (`esf check` rule ESF-C008
        // proves the configured workload cannot get here).
        assert!(
            k < 1 << TXN_NODE_SHIFT,
            "txn-id namespace overflow at node {}: counter {k} no longer fits \
             (node+1)<<{TXN_NODE_SHIFT} | k — ids would collide across nodes",
            self.cur
        );
        ((self.cur as u64 + 1) << TXN_NODE_SHIFT) | k
    }

    /// Schedule a fully keyed event from the current origin, diverting
    /// cross-domain targets into the outbound buffer in partitioned runs.
    fn push_ev(&mut self, ts: Ps, target: NodeId, payload: Payload) {
        debug_assert!(ts >= self.now, "scheduling into the past");
        let seq = self.sched_seq[self.cur];
        self.sched_seq[self.cur] += 1;
        let ev = Ev {
            time: ts,
            src: self.cur as u32,
            seq,
            target,
            payload,
        };
        if let Some(p) = self.part.as_mut() {
            if p.domain_of[target] != p.me {
                p.outbound.push(ev);
                return;
            }
        }
        self.queue.push(ev);
    }

    /// Schedule `payload` for `target` after `delay`.
    pub fn after(&mut self, delay: Ps, target: NodeId, payload: Payload) {
        self.push_ev(self.now + delay, target, payload);
    }

    /// Schedule `payload` for `target` at absolute time `ts` (clamped to
    /// now — used by components parking on a known-busy resource).
    pub fn at(&mut self, ts: Ps, target: NodeId, payload: Payload) {
        self.push_ev(ts.max(self.now), target, payload);
    }

    /// Forward `pkt` one hop toward its destination. Adds queueing/bus time
    /// to the packet breakdown and schedules its arrival at the neighbor.
    /// `extra_delay` is processing latency at the current node charged
    /// before the packet reaches the link (switching time, port delay...).
    /// Returns `false` if the destination is unroutable (packet dropped
    /// and counted) so issuers can reclaim queue slots.
    pub fn forward(&mut self, pkt: Packet, extra_delay: Ps) -> bool {
        self.forward_boxed(Box::new(pkt), extra_delay)
    }

    /// Like `forward` but reuses the packet's existing allocation (the
    /// per-hop path: switches re-forward the same box).
    ///
    /// Drop accounting contract (audited for the partitioned engine, see
    /// `tests/partition.rs`): an unroutable packet is counted in `dropped`
    /// and **nothing else** — no link was reserved, so no `busy_ps` can be
    /// missing, and the txn id it carried came from a per-node counter, so
    /// the id sequence stays identical whether or not the drop happened on
    /// a partition boundary or during warm-up.
    pub fn forward_boxed(&mut self, mut pkt: Box<Packet>, extra_delay: Ps) -> bool {
        let u = pkt.at;
        if u == pkt.dst {
            // Already at destination: deliver directly.
            self.after(extra_delay, u, Payload::Packet(pkt));
            return true;
        }
        let Some((next, link)) = self.routing.next_hop(
            u,
            pkt.src,
            pkt.dst,
            self.strategy,
            &self.net,
            &self.topo,
            self.now,
        ) else {
            self.dropped += 1;
            return false;
        };
        let dir = dir_of(&self.topo, link, u);
        let depart = self.now + extra_delay;
        let x = self.net.transmit(link, dir, pkt.payload_bytes, depart);
        pkt.breakdown.queue_ps += x.queued;
        pkt.breakdown.bus_ps += x.arrive - x.start;
        pkt.breakdown.hops += 1;
        pkt.at = next;
        self.push_ev(x.arrive, next, Payload::Packet(pkt));
        true
    }

    /// Register one requester that will perform a warm-up phase.
    pub fn expect_warmup(&mut self) {
        self.warmups_pending += 1;
    }

    /// Called by a requester when its warm-up quota completes. When the
    /// last one reports, the measurement epoch begins (paper: "perform
    /// warming-up requests ... only collect results under steady-states").
    pub fn warmup_done(&mut self) {
        // Always-on (it used to be a `debug_assert!` that release builds
        // stripped): an unmatched call would wrap `warmups_pending` to
        // usize::MAX and the measurement epoch would never start.
        assert!(
            self.warmups_pending > 0,
            "warmup_done without a matching expect_warmup: \
             warmups_pending would underflow and stall the epoch start"
        );
        self.warmups_pending -= 1;
        if self.warmups_pending == 0 {
            let now = self.now;
            self.net.start_epoch(now);
            self.collecting = true;
        }
    }

    pub fn epoch_span(&self) -> Ps {
        self.net.epoch_end.saturating_sub(self.net.epoch_start)
    }

    /// Clone this shard for one event domain of a partitioned run: same
    /// immutable topology/routing, a private `NetState` clone and counter
    /// vectors, and the given local queue + partition context. Only called
    /// after warm-up (collection running), so the clone starts collecting.
    fn domain_shard(&self, queue: EventQueue, me: u32, domain_of: Arc<Vec<u32>>) -> Shared {
        debug_assert!(self.collecting, "domains split before the epoch opened");
        Shared {
            now: self.now,
            queue,
            topo: self.topo.clone(),
            routing: self.routing.clone(),
            net: self.net.clone(),
            strategy: self.strategy,
            warmups_pending: 0,
            collecting: true,
            cur: self.topo.n(),
            sched_seq: self.sched_seq.clone(),
            txn_seq: self.txn_seq.clone(),
            dropped: 0,
            part: Some(PartCtx {
                me,
                domain_of,
                outbound: Vec::new(),
            }),
        }
    }

    /// Drain the cross-domain events produced since the last barrier.
    fn take_outbound(&mut self) -> Vec<Ev> {
        match self.part.as_mut() {
            Some(p) => std::mem::take(&mut p.outbound),
            None => Vec::new(),
        }
    }
}

/// A simulated device. One component per topology node, registered in node
/// id order. `Send` because the partitioned engine moves components onto
/// their domain's worker thread.
pub trait Component: Any + Send {
    /// Schedule initial events (issue ticks etc.).
    fn start(&mut self, _ctx: &mut Shared) {}
    /// Handle one event.
    fn handle(&mut self, payload: Payload, ctx: &mut Shared);
    /// Serialize this component's mutable state for [`Engine::snapshot`].
    /// Stateless components keep the no-op default; stateful ones must
    /// write every field `handle` can mutate, in a fixed deterministic
    /// order (see `engine::snapshot` for the format contract).
    fn snapshot(&self, _w: &mut crate::util::snap::SnapWriter) {}
    /// Rebuild the state written by [`Component::snapshot`]. Called on a
    /// freshly built component of the same config, in node order.
    fn restore(&mut self, _r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        Ok(())
    }
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// Exchange accounting of one partitioned run — filled in by
/// [`Engine::run_partitioned`] (stays `None` when the run fell back to
/// the sequential loop). Pure bookkeeping: none of these counters feed
/// back into simulation state, so recording them costs determinism
/// nothing. The sparse-exchange acceptance numbers in
/// `BENCH_hotpath.json` come from here.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IntraStats {
    /// Event domains the fabric was cut into.
    pub domains: usize,
    /// Conservative barrier rounds executed after the warm-up prefix.
    pub windows: u64,
    /// Barrier rounds in which at least one draining domain was granted
    /// a window end beyond the classic `tmin + lookahead` bound (the
    /// adaptive multi-lookahead jump; always 0 under
    /// [`parallel::BarrierMode::FixedWindow`]).
    pub widened_windows: u64,
    /// Directed neighbor channels the sparse exchange opened (two per
    /// cut-adjacent domain pair). The all-to-all baseline would open
    /// `domains * (domains - 1)`.
    pub channels: usize,
    /// Batch messages sent over those channels. The fixed-window
    /// protocol sends one per channel per window (`windows * channels`);
    /// the adaptive protocol only ever sends non-empty batches, so
    /// `messages + elided_tokens == windows * channels` in both modes.
    pub messages: u64,
    /// Messages that carried the compact "no traffic" token instead of
    /// an event batch (fixed-window mode only; the adaptive protocol
    /// elides them — see `elided_tokens`).
    pub quiet_messages: u64,
    /// Channel-rounds where the fixed-window protocol would have sent a
    /// quiet token but the adaptive protocol sent nothing at all:
    /// `windows * channels - messages`.
    pub elided_tokens: u64,
    /// Cross-domain events actually exchanged.
    pub events_exchanged: u64,
    /// Rounds in which a domain executed at least one event past its
    /// certified horizon ([`parallel::BarrierMode::Speculative`] only;
    /// summed over domains). Deterministic: the speculation bound is a
    /// pure function of the granted window, never of thread timing.
    pub speculative_windows: u64,
    /// Speculation stints undone — a straggler batch arrived behind the
    /// speculative frontier, or the next certified window stopped short
    /// of it — by restoring the domain's in-memory checkpoint and
    /// re-executing deterministically (Speculative only).
    pub rollbacks: u64,
    /// Events executed speculatively and then rolled back. The
    /// re-execution recounts them, so `events_processed` still matches
    /// the sequential engine exactly; this counter is the honest price
    /// of optimism (Speculative only).
    pub wasted_events: u64,
    /// Rounds where the commit frontier — the global minimum over every
    /// domain's earliest pending or in-flight event time, the
    /// deterministic GVT analogue rollback checkpoints may never trail —
    /// strictly advanced (Speculative only).
    pub committed_frontier_advances: u64,
}

/// The simulation engine: component registry + event loop.
pub struct Engine {
    pub shared: Shared,
    components: Vec<Box<dyn Component>>,
    pub events_processed: u64,
    /// Exchange accounting of the last partitioned run (see [`IntraStats`]).
    pub intra_stats: Option<IntraStats>,
    started: bool,
    /// Set by [`Engine::restore`] when the snapshot was taken at a
    /// barrier-quiescent point (the warm-up→collection flip): the only
    /// started state `run_partitioned` accepts. Mid-run checkpoints
    /// restore with this `false` and must continue sequentially.
    restored_quiescent: bool,
}

impl Engine {
    pub fn new(shared: Shared) -> Engine {
        Engine {
            shared,
            components: Vec::new(),
            events_processed: 0,
            intra_stats: None,
            started: false,
            restored_quiescent: false,
        }
    }

    /// Register the component for the next node id; panics if registration
    /// order diverges from topology node order.
    pub fn register(&mut self, c: Box<dyn Component>) -> NodeId {
        let id = self.components.len();
        assert!(
            id < self.shared.topo.n(),
            "more components than topology nodes"
        );
        self.components.push(c);
        id
    }

    /// First-run initialization: `start()` hooks in node order, and epoch
    /// opening when nobody warms up.
    fn start_components(&mut self) {
        assert_eq!(
            self.components.len(),
            self.shared.topo.n(),
            "every topology node needs a component"
        );
        self.started = true;
        for i in 0..self.components.len() {
            self.shared.set_origin(i);
            self.components[i].start(&mut self.shared);
        }
        self.shared.set_origin(self.shared.topo.n());
        // If nobody needs warm-up, collection starts immediately.
        if self.shared.warmups_pending == 0 {
            self.shared.net.start_epoch(self.shared.now);
            self.shared.collecting = true;
        }
    }

    /// Run to completion (event queue drained) or until `max_events`.
    /// Returns the number of events processed. May be called repeatedly
    /// (incremental use, e.g. the gem5-style memory wrapper): component
    /// `start()` hooks and epoch initialization fire only on the first
    /// call.
    pub fn run(&mut self, max_events: u64) -> u64 {
        if !self.started {
            self.start_components();
        } else if self.shared.collecting && !self.shared.net.collecting {
            // Re-entry after a previous run() closed the epoch at its
            // horizon: resume accumulating link utilization without
            // resetting the counters, so incremental use (the gem5-style
            // wrapper path) measures the same epoch a single run would.
            self.shared.net.resume_epoch();
        }
        let mut n = 0;
        while let Some(ev) = self.shared.queue.pop() {
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.shared.cur = ev.target;
            self.components[ev.target].handle(ev.payload, &mut self.shared);
            n += 1;
            if n >= max_events {
                break;
            }
        }
        self.shared.set_origin(self.shared.topo.n());
        let now = self.shared.now;
        self.shared.net.end_epoch(now);
        self.events_processed += n;
        n
    }

    /// Run every event strictly before `bound`, then close the epoch at
    /// the current horizon — the time-stepped variant of [`Engine::run`]
    /// used by `esf run --checkpoint-every`. Repeated calls accumulate
    /// exactly like a single [`Engine::run`] (same resume-epoch re-entry
    /// as incremental `run()` stepping, pinned by
    /// `incremental_runs_accumulate_like_a_single_run`).
    pub fn run_until(&mut self, bound: Ps) -> u64 {
        if !self.started {
            self.start_components();
        } else if self.shared.collecting && !self.shared.net.collecting {
            self.shared.net.resume_epoch();
        }
        let mut n = 0;
        while let Some(ev) = self.shared.queue.pop_if_before(bound) {
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.shared.cur = ev.target;
            self.components[ev.target].handle(ev.payload, &mut self.shared);
            n += 1;
        }
        self.shared.set_origin(self.shared.topo.n());
        let now = self.shared.now;
        self.shared.net.end_epoch(now);
        self.events_processed += n;
        n
    }

    /// Run the warm-up prefix only: process events until the measurement
    /// epoch opens (or the queue drains), leaving the epoch OPEN — the
    /// exact state `parallel::run_partitioned` reaches at the end of its
    /// sequential Phase A. This is the barrier-quiescent snapshot point
    /// for warm-start prefix sharing: a snapshot taken here may be
    /// restored and continued by either `run()` or `run_partitioned()`.
    /// Must be the engine's first run.
    pub fn run_until_collecting(&mut self) -> u64 {
        assert!(
            !self.started,
            "run_until_collecting must be an engine's first run"
        );
        self.start_components();
        let mut n = 0;
        while !self.shared.collecting {
            let Some(ev) = self.shared.queue.pop() else {
                break;
            };
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.shared.cur = ev.target;
            self.components[ev.target].handle(ev.payload, &mut self.shared);
            n += 1;
        }
        self.shared.set_origin(self.shared.topo.n());
        self.events_processed += n;
        n
    }

    /// The sequential event loop under its A/B-reference name: the
    /// partitioned engine ([`Engine::run_partitioned`]) must be
    /// byte-identical to this, exactly like `EventQueue::reference_heap()`
    /// is the reference for the ladder queue (`tests/partition.rs`).
    pub fn reference_sequential(&mut self) -> u64 {
        self.run(u64::MAX)
    }

    /// Run to completion on `intra_jobs` worker threads by splitting the
    /// fabric into conservative event domains (see `engine::parallel`),
    /// balanced by the default traffic weighting
    /// ([`crate::interconnect::WeightModel::Traffic`]). Output is
    /// byte-identical to [`Engine::reference_sequential`];
    /// `intra_jobs <= 1` (or a fabric that cannot be cut) simply runs the
    /// sequential loop. Must be the first run of this engine, and always
    /// drains the queue (no `max_events` stepping — incremental callers
    /// keep using [`Engine::run`]).
    pub fn run_partitioned(&mut self, intra_jobs: usize) -> u64 {
        parallel::run_partitioned(
            self,
            intra_jobs,
            crate::interconnect::WeightModel::Traffic,
            parallel::BarrierMode::Adaptive,
        )
    }

    /// [`Engine::run_partitioned`] with explicit weighting AND barrier
    /// mode — the full A/B surface: every (weighting, mode) combination
    /// must produce byte-identical output (only wall-clock, window and
    /// exchange volume may move), which `tests/partition.rs` pins.
    /// [`parallel::BarrierMode::FixedWindow`] is the PR 4/5 lockstep
    /// oracle; [`parallel::BarrierMode::Adaptive`] (the default
    /// everywhere else) widens windows from the coordinator's horizon
    /// relaxation and elides quiet tokens.
    pub fn run_partitioned_opts(
        &mut self,
        intra_jobs: usize,
        model: crate::interconnect::WeightModel,
        mode: parallel::BarrierMode,
    ) -> u64 {
        parallel::run_partitioned(self, intra_jobs, model, mode)
    }

    /// [`Engine::run_partitioned`] with an explicit domain weighting —
    /// the A/B surface for the node-count oracle: every weighting must
    /// produce byte-identical output (only wall-clock and exchange
    /// volume may move), which `tests/partition.rs` pins.
    pub fn run_partitioned_model(
        &mut self,
        intra_jobs: usize,
        model: crate::interconnect::WeightModel,
    ) -> u64 {
        parallel::run_partitioned(self, intra_jobs, model, parallel::BarrierMode::Adaptive)
    }

    /// Typed access to a component (post-run stats extraction).
    pub fn component<T: 'static>(&self, id: NodeId) -> Option<&T> {
        self.components.get(id)?.as_any().downcast_ref::<T>()
    }

    pub fn component_mut<T: 'static>(&mut self, id: NodeId) -> Option<&mut T> {
        self.components.get_mut(id)?.as_any_mut().downcast_mut::<T>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::{LinkCfg, NodeKind};

    struct Echo {
        id: NodeId,
        peer: NodeId,
        got: Vec<Ps>,
        bounces: u64,
    }

    impl Component for Echo {
        fn start(&mut self, ctx: &mut Shared) {
            if self.id == 0 {
                ctx.after(0, self.id, Payload::Timer(0, 0));
            }
        }
        fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
            match payload {
                Payload::Timer(..) => {
                    let id = ctx.txn_id();
                    let pkt = Packet::request(
                        id,
                        crate::proto::Opcode::MemRd,
                        self.id,
                        self.peer,
                        0,
                        ctx.now,
                    );
                    ctx.forward(pkt, 0);
                }
                Payload::Packet(pkt) => {
                    self.got.push(ctx.now);
                    if self.bounces > 0 {
                        self.bounces -= 1;
                        let rsp = pkt.response(false);
                        ctx.forward(rsp, 0);
                    }
                }
                _ => {}
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    fn two_node_engine() -> Engine {
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Memory);
        t.add_link(
            a,
            b,
            LinkCfg {
                bandwidth_gbps: 64.0,
                latency: time::NS,
                duplex: crate::interconnect::Duplex::Full,
                turnaround: 0,
                header_bytes: 0,
            },
        );
        let routing = Routing::build_bfs(&t);
        let shared = Shared::new(t, routing, Strategy::Oblivious);
        let mut e = Engine::new(shared);
        e.register(Box::new(Echo {
            id: 0,
            peer: 1,
            got: vec![],
            bounces: 0,
        }));
        e.register(Box::new(Echo {
            id: 1,
            peer: 0,
            got: vec![],
            bounces: 1,
        }));
        e
    }

    #[test]
    fn request_response_roundtrip_timing() {
        let mut e = two_node_engine();
        let n = e.run(1_000);
        assert!(n >= 3);
        // a's MemRd: header-only (0 payload, 0 header cfg) => ser 0 + 1ns
        // latency; b's response: 64B payload = 1ns ser + 1ns latency.
        let a = e.component::<Echo>(0).unwrap();
        assert_eq!(a.got, vec![3 * time::NS]);
        let b = e.component::<Echo>(1).unwrap();
        assert_eq!(b.got, vec![time::NS]);
    }

    #[test]
    fn event_order_is_deterministic() {
        let run = || {
            let mut e = two_node_engine();
            e.run(1_000);
            e.component::<Echo>(0).unwrap().got.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn max_events_bounds_run() {
        let mut e = two_node_engine();
        let n = e.run(1);
        assert_eq!(n, 1);
    }

    #[test]
    fn epoch_starts_immediately_without_warmups() {
        let mut e = two_node_engine();
        e.run(1_000);
        assert!(e.shared.collecting);
        assert_eq!(e.shared.net.epoch_start, 0);
    }

    /// Txn ids must be minted from per-node counters: unique across
    /// nodes, sequential per node — the property that keeps id streams
    /// identical between the sequential and partitioned engines.
    #[test]
    fn txn_ids_are_per_node_namespaced() {
        let mut e = two_node_engine();
        e.shared.set_origin(0);
        let a0 = e.shared.txn_id();
        let a1 = e.shared.txn_id();
        e.shared.set_origin(1);
        let b0 = e.shared.txn_id();
        assert_eq!(a1, a0 + 1);
        assert_ne!(a0, b0);
        assert_eq!(a0 >> 40, 1); // node 0 -> namespace 1
        assert_eq!(b0 >> 40, 2);
    }

    /// The namespace guard must hold in release builds too (it used to be
    /// a `debug_assert!` that optimized out, silently colliding ids).
    #[test]
    #[should_panic(expected = "txn-id namespace overflow")]
    fn txn_id_overflow_panics_in_any_build() {
        let mut e = two_node_engine();
        e.shared.set_origin(0);
        // Last representable per-node counter value still mints cleanly...
        e.shared.txn_seq[0] = (1 << TXN_NODE_SHIFT) - 1;
        let last = e.shared.txn_id();
        assert_eq!(last, (1u64 << TXN_NODE_SHIFT) | ((1 << TXN_NODE_SHIFT) - 1));
        // ...and the next mint must fail loudly instead of aliasing node 1.
        e.shared.txn_id();
    }

    /// The warm-up underflow guard must hold in release builds too (it
    /// used to be a `debug_assert!` that optimized out — an unmatched
    /// `warmup_done` wrapped `warmups_pending` to usize::MAX and the
    /// measurement epoch silently never started).
    #[test]
    #[should_panic(expected = "warmup_done without a matching expect_warmup")]
    fn warmup_done_underflow_panics_in_any_build() {
        let mut e = two_node_engine();
        e.shared.expect_warmup();
        e.shared.warmup_done(); // matched: epoch opens
        assert!(e.shared.collecting);
        e.shared.warmup_done(); // unmatched: must fail loudly
    }

    #[test]
    #[should_panic(expected = "set_origin")]
    fn set_origin_rejects_out_of_range_node() {
        let mut e = two_node_engine();
        let n = e.shared.topo.n();
        e.shared.set_origin(n + 1); // n is the external slot; n+1 is invalid
    }

    /// Epoch re-entry regression: a second incremental `run()` call must
    /// keep accumulating link utilization (it used to stay closed after
    /// the first return's `end_epoch`, silently zeroing later traffic).
    #[test]
    fn incremental_runs_accumulate_like_a_single_run() {
        let mut one_shot = two_node_engine();
        one_shot.run(1_000);

        let mut stepped = two_node_engine();
        while stepped.run(1) > 0 {}

        assert!(stepped.shared.collecting);
        assert_eq!(
            stepped.shared.net.payload_bytes(0),
            one_shot.shared.net.payload_bytes(0),
            "stepped runs must count the same link payload"
        );
        assert_eq!(stepped.shared.net.epoch_start, one_shot.shared.net.epoch_start);
        assert_eq!(stepped.shared.net.epoch_end, one_shot.shared.net.epoch_end);
        let (a, b) = (stepped.shared.net.bus_utility(0), one_shot.shared.net.bus_utility(0));
        assert!((a - b).abs() < 1e-12, "utilization {a} vs {b}");
    }

    #[test]
    fn fifo_tie_break_on_same_timestamp() {
        for mut q in [EventQueue::default(), EventQueue::reference_heap()] {
            q.schedule(5, 0, Payload::Timer(1, 0));
            q.schedule(5, 0, Payload::Timer(2, 0));
            q.schedule(3, 0, Payload::Timer(0, 0));
            let tags: Vec<u64> = std::iter::from_fn(|| q.pop())
                .map(|e| match e.payload {
                    Payload::Timer(t, _) => t,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(tags, vec![0, 1, 2]);
        }
    }

    /// Keyed events tie-break by `(src, seq)` after time — the canonical
    /// order both engines share. Lower scheduling node pops first among
    /// same-time ties, per-node FIFO within one scheduler.
    #[test]
    fn keyed_tie_break_is_src_then_seq() {
        for mut q in [EventQueue::default(), EventQueue::reference_heap()] {
            let mk = |src: u32, seq: u64, tag: u64| Ev {
                time: 9,
                src,
                seq,
                target: 0,
                payload: Payload::Timer(tag, 0),
            };
            q.push(mk(7, 0, 2));
            q.push(mk(3, 5, 0));
            q.push(mk(7, 1, 3));
            q.push(mk(3, 6, 1));
            let tags: Vec<u64> = std::iter::from_fn(|| q.pop())
                .map(|e| match e.payload {
                    Payload::Timer(t, _) => t,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(tags, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn pop_if_before_respects_bound_and_preserves_order() {
        let mut q = EventQueue::default();
        for i in 0..50u64 {
            q.schedule(i * 10, 0, Payload::Timer(i, 0));
        }
        // Drain in two windows; order must equal a straight drain.
        let mut got = Vec::new();
        while let Some(ev) = q.pop_if_before(200) {
            got.push(ev.time);
        }
        assert_eq!(q.next_time(), Some(200));
        assert_eq!(q.len(), 30);
        while let Some(ev) = q.pop_if_before(Ps::MAX) {
            got.push(ev.time);
        }
        assert!(q.is_empty());
        assert_eq!(got, (0..50).map(|i| i * 10).collect::<Vec<_>>());
    }

    /// Ladder rollover: widely spread timestamps force several window
    /// rebuilds from the overflow tail; global key order must survive
    /// every one of them.
    #[test]
    fn ladder_bucket_rollover_keeps_global_order() {
        let mut q = EventQueue::default();
        for i in 0..1000u64 {
            // Scattered across ~7 seconds with dense sub-clusters.
            let t = (i % 7) * 1_000_000_000_000 + (i * 37) % 1000;
            q.schedule(t, 0, Payload::Timer(i, 0));
        }
        assert_eq!(q.len(), 1000);
        let mut last: Option<(Ps, u32, u64)> = None;
        let mut n = 0;
        while let Some(ev) = q.pop() {
            if let Some(prev) = last {
                assert!(ev.key() > prev, "order violated at event {n}");
            }
            last = Some(ev.key());
            n += 1;
        }
        assert_eq!(n, 1000);
        assert!(q.is_empty());
    }

    /// Scheduling at the *current* time while the active bucket drains
    /// (the zero-delay self-event pattern) must keep FIFO order among the
    /// ties and precede every later timestamp.
    #[test]
    fn ladder_same_time_insert_during_drain() {
        let mut q = EventQueue::default();
        for i in 0..100u64 {
            q.schedule(i * 10, 0, Payload::Timer(i, 0));
        }
        let mut order: Vec<(Ps, u64)> = Vec::new();
        let mut injected = 0u64;
        while let Some(ev) = q.pop() {
            order.push((ev.time, ev.seq));
            if injected < 10 {
                injected += 1;
                // Same-time echo: must pop after existing same-time ties
                // (higher seq) but before time+10.
                q.schedule(ev.time, 0, Payload::Timer(1000 + injected, 0));
            }
        }
        assert_eq!(order.len(), 110);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(order, sorted, "pop order must equal (time, seq) order");
    }

    /// The ladder queue must agree with the seed's binary-heap reference
    /// on arbitrary schedule/pop interleavings — this is the tie-break
    /// contract every simulation output depends on. Keys mix compat and
    /// keyed scheduling from several `src` nodes.
    #[test]
    fn ladder_matches_heap_reference_under_random_churn() {
        use crate::util::prop::forall;
        forall(
            "ladder vs heap event order",
            30,
            |rng| {
                let n = 50 + rng.gen_range(200);
                (0..n)
                    .map(|_| {
                        let delay = if rng.chance(0.05) {
                            rng.gen_range(1 << 40) // far-future outlier
                        } else {
                            rng.gen_range(1_000_000)
                        };
                        (rng.gen_range(3), delay, rng.gen_range(4) as u32)
                    })
                    .collect::<Vec<(u64, u64, u32)>>()
            },
            |ops| {
                let mut lad = EventQueue::default();
                let mut heap = EventQueue::reference_heap();
                let mut now = 0u64;
                let mut per_src = [0u64; 4];
                let check = |a: Option<Ev>, b: Option<Ev>| -> Result<Option<Ps>, String> {
                    match (a, b) {
                        (None, None) => Ok(None),
                        (Some(x), Some(y)) => {
                            if x.key() != y.key() {
                                return Err(format!(
                                    "diverged: ladder {:?} vs heap {:?}",
                                    x.key(),
                                    y.key()
                                ));
                            }
                            Ok(Some(x.time))
                        }
                        _ => Err("one queue drained before the other".into()),
                    }
                };
                for &(pops, delay, src) in ops {
                    let seq = per_src[src as usize];
                    per_src[src as usize] += 1;
                    for q in [&mut lad, &mut heap] {
                        q.push(Ev {
                            time: now + delay,
                            src,
                            seq,
                            target: 0,
                            payload: Payload::Timer(seq, 0),
                        });
                    }
                    for _ in 0..pops {
                        if let Some(t) = check(lad.pop(), heap.pop())? {
                            now = t;
                        }
                    }
                    if lad.len() != heap.len() {
                        return Err(format!("len {} vs {}", lad.len(), heap.len()));
                    }
                }
                loop {
                    match check(lad.pop(), heap.pop())? {
                        Some(_) => {}
                        None => break,
                    }
                }
                Ok(())
            },
        );
    }
}
