//! Discrete-event simulation core.
//!
//! Single-threaded, deterministic: events are totally ordered by
//! `(time, seq)` where `seq` is the scheduling order, so identical seeds
//! produce identical event traces. Components never hold references to
//! each other — all interaction flows through scheduled events plus the
//! passive shared state (`Shared`: link states, routing tables, epoch
//! control), which is what lets one `&mut` context serve every handler.

pub mod time;

use crate::interconnect::{dir_of, NetState, Routing, Strategy, Topology};
use crate::proto::{NodeId, Packet};
use std::any::Any;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use time::Ps;

/// Event payloads delivered to components.
#[derive(Clone, Debug)]
pub enum Payload {
    /// A transaction-layer message arriving at this node. Boxed: heap
    /// entries shrink from ~140B to 32B, cutting sift traffic in the
    /// event queue (see EXPERIMENTS.md §Perf).
    Packet(Box<Packet>),
    /// Requester self-tick: try to issue the next request.
    IssueTick,
    /// Generic component-defined timer (tag, data).
    Timer(u64, u64),
}

#[derive(Debug)]
struct Ev {
    time: Ps,
    seq: u64,
    target: NodeId,
    payload: Payload,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed compare: earliest time first, then lowest
        // sequence number (schedule order) for a stable tie-break.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Priority queue of pending events.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Ev>,
    next_seq: u64,
}

impl EventQueue {
    pub fn schedule(&mut self, time: Ps, target: NodeId, payload: Payload) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Ev {
            time,
            seq,
            target,
            payload,
        });
    }

    fn pop(&mut self) -> Option<Ev> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Shared simulation state handed to every event handler.
pub struct Shared {
    pub now: Ps,
    pub queue: EventQueue,
    pub topo: Topology,
    pub routing: Routing,
    pub net: NetState,
    pub strategy: Strategy,
    /// Requesters still in their warm-up phase; when it reaches zero the
    /// measurement epoch starts (stats reset, collection begins).
    warmups_pending: usize,
    pub collecting: bool,
    next_txn: u64,
    /// Count of dropped packets (no route) — failure-injection visibility.
    pub dropped: u64,
}

impl Shared {
    pub fn new(topo: Topology, routing: Routing, strategy: Strategy) -> Shared {
        let net = NetState::for_topology(&topo);
        Shared {
            now: 0,
            queue: EventQueue::default(),
            topo,
            routing,
            net,
            strategy,
            warmups_pending: 0,
            collecting: false,
            next_txn: 0,
            dropped: 0,
        }
    }

    pub fn txn_id(&mut self) -> u64 {
        let id = self.next_txn;
        self.next_txn += 1;
        id
    }

    /// Schedule `payload` for `target` after `delay`.
    pub fn after(&mut self, delay: Ps, target: NodeId, payload: Payload) {
        self.queue.schedule(self.now + delay, target, payload);
    }

    /// Forward `pkt` one hop toward its destination. Adds queueing/bus time
    /// to the packet breakdown and schedules its arrival at the neighbor.
    /// `extra_delay` is processing latency at the current node charged
    /// before the packet reaches the link (switching time, port delay...).
    /// Returns `false` if the destination is unroutable (packet dropped
    /// and counted) so issuers can reclaim queue slots.
    pub fn forward(&mut self, pkt: Packet, extra_delay: Ps) -> bool {
        self.forward_boxed(Box::new(pkt), extra_delay)
    }

    /// Like `forward` but reuses the packet's existing allocation (the
    /// per-hop path: switches re-forward the same box).
    pub fn forward_boxed(&mut self, mut pkt: Box<Packet>, extra_delay: Ps) -> bool {
        let u = pkt.at;
        if u == pkt.dst {
            // Already at destination: deliver directly.
            self.after(extra_delay, u, Payload::Packet(pkt));
            return true;
        }
        let Some((next, link)) = self.routing.next_hop(
            u,
            pkt.src,
            pkt.dst,
            self.strategy,
            &self.net,
            &self.topo,
            self.now,
        ) else {
            self.dropped += 1;
            return false;
        };
        let dir = dir_of(&self.topo, link, u);
        let depart = self.now + extra_delay;
        let x = self.net.transmit(link, dir, pkt.payload_bytes, depart);
        pkt.breakdown.queue_ps += x.queued;
        pkt.breakdown.bus_ps += x.arrive - x.start;
        pkt.breakdown.hops += 1;
        pkt.at = next;
        self.queue.schedule(x.arrive, next, Payload::Packet(pkt));
        true
    }

    /// Register one requester that will perform a warm-up phase.
    pub fn expect_warmup(&mut self) {
        self.warmups_pending += 1;
    }

    /// Called by a requester when its warm-up quota completes. When the
    /// last one reports, the measurement epoch begins (paper: "perform
    /// warming-up requests ... only collect results under steady-states").
    pub fn warmup_done(&mut self) {
        debug_assert!(self.warmups_pending > 0);
        self.warmups_pending -= 1;
        if self.warmups_pending == 0 {
            let now = self.now;
            self.net.start_epoch(now);
            self.collecting = true;
        }
    }

    pub fn epoch_span(&self) -> Ps {
        self.net.epoch_end.saturating_sub(self.net.epoch_start)
    }
}

/// A simulated device. One component per topology node, registered in node
/// id order.
pub trait Component: Any {
    /// Schedule initial events (issue ticks etc.).
    fn start(&mut self, _ctx: &mut Shared) {}
    /// Handle one event.
    fn handle(&mut self, payload: Payload, ctx: &mut Shared);
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// The simulation engine: component registry + event loop.
pub struct Engine {
    pub shared: Shared,
    components: Vec<Box<dyn Component>>,
    pub events_processed: u64,
    started: bool,
}

impl Engine {
    pub fn new(shared: Shared) -> Engine {
        Engine {
            shared,
            components: Vec::new(),
            events_processed: 0,
            started: false,
        }
    }

    /// Register the component for the next node id; panics if registration
    /// order diverges from topology node order.
    pub fn register(&mut self, c: Box<dyn Component>) -> NodeId {
        let id = self.components.len();
        assert!(
            id < self.shared.topo.n(),
            "more components than topology nodes"
        );
        self.components.push(c);
        id
    }

    /// Run to completion (event queue drained) or until `max_events`.
    /// Returns the number of events processed. May be called repeatedly
    /// (incremental use, e.g. the gem5-style memory wrapper): component
    /// `start()` hooks and epoch initialization fire only on the first
    /// call.
    pub fn run(&mut self, max_events: u64) -> u64 {
        assert_eq!(
            self.components.len(),
            self.shared.topo.n(),
            "every topology node needs a component"
        );
        if !self.started {
            self.started = true;
            for i in 0..self.components.len() {
                self.components[i].start(&mut self.shared);
            }
            // If nobody needs warm-up, collection starts immediately.
            if self.shared.warmups_pending == 0 {
                self.shared.net.start_epoch(self.shared.now);
                self.shared.collecting = true;
            }
        } else if self.shared.collecting && !self.shared.net.collecting {
            // Re-entry after a previous run() closed the epoch at its
            // horizon: resume accumulating link utilization without
            // resetting the counters, so incremental use (the gem5-style
            // wrapper path) measures the same epoch a single run would.
            self.shared.net.resume_epoch();
        }
        let mut n = 0;
        while let Some(ev) = self.shared.queue.pop() {
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.components[ev.target].handle(ev.payload, &mut self.shared);
            n += 1;
            if n >= max_events {
                break;
            }
        }
        let now = self.shared.now;
        self.shared.net.end_epoch(now);
        self.events_processed += n;
        n
    }

    /// Typed access to a component (post-run stats extraction).
    pub fn component<T: 'static>(&self, id: NodeId) -> Option<&T> {
        self.components.get(id)?.as_any().downcast_ref::<T>()
    }

    pub fn component_mut<T: 'static>(&mut self, id: NodeId) -> Option<&mut T> {
        self.components.get_mut(id)?.as_any_mut().downcast_mut::<T>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::{LinkCfg, NodeKind};

    struct Echo {
        id: NodeId,
        peer: NodeId,
        got: Vec<Ps>,
        bounces: u64,
    }

    impl Component for Echo {
        fn start(&mut self, ctx: &mut Shared) {
            if self.id == 0 {
                ctx.after(0, self.id, Payload::Timer(0, 0));
            }
        }
        fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
            match payload {
                Payload::Timer(..) => {
                    let id = ctx.txn_id();
                    let pkt = Packet::request(
                        id,
                        crate::proto::Opcode::MemRd,
                        self.id,
                        self.peer,
                        0,
                        ctx.now,
                    );
                    ctx.forward(pkt, 0);
                }
                Payload::Packet(pkt) => {
                    self.got.push(ctx.now);
                    if self.bounces > 0 {
                        self.bounces -= 1;
                        let rsp = pkt.response(false);
                        ctx.forward(rsp, 0);
                    }
                }
                _ => {}
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    fn two_node_engine() -> Engine {
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Memory);
        t.add_link(
            a,
            b,
            LinkCfg {
                bandwidth_gbps: 64.0,
                latency: time::NS,
                duplex: crate::interconnect::Duplex::Full,
                turnaround: 0,
                header_bytes: 0,
            },
        );
        let routing = Routing::build_bfs(&t);
        let shared = Shared::new(t, routing, Strategy::Oblivious);
        let mut e = Engine::new(shared);
        e.register(Box::new(Echo {
            id: 0,
            peer: 1,
            got: vec![],
            bounces: 0,
        }));
        e.register(Box::new(Echo {
            id: 1,
            peer: 0,
            got: vec![],
            bounces: 1,
        }));
        e
    }

    #[test]
    fn request_response_roundtrip_timing() {
        let mut e = two_node_engine();
        let n = e.run(1_000);
        assert!(n >= 3);
        // a's MemRd: header-only (0 payload, 0 header cfg) => ser 0 + 1ns
        // latency; b's response: 64B payload = 1ns ser + 1ns latency.
        let a = e.component::<Echo>(0).unwrap();
        assert_eq!(a.got, vec![3 * time::NS]);
        let b = e.component::<Echo>(1).unwrap();
        assert_eq!(b.got, vec![time::NS]);
    }

    #[test]
    fn event_order_is_deterministic() {
        let run = || {
            let mut e = two_node_engine();
            e.run(1_000);
            e.component::<Echo>(0).unwrap().got.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn max_events_bounds_run() {
        let mut e = two_node_engine();
        let n = e.run(1);
        assert_eq!(n, 1);
    }

    #[test]
    fn epoch_starts_immediately_without_warmups() {
        let mut e = two_node_engine();
        e.run(1_000);
        assert!(e.shared.collecting);
        assert_eq!(e.shared.net.epoch_start, 0);
    }

    /// Epoch re-entry regression: a second incremental `run()` call must
    /// keep accumulating link utilization (it used to stay closed after
    /// the first return's `end_epoch`, silently zeroing later traffic).
    #[test]
    fn incremental_runs_accumulate_like_a_single_run() {
        let mut one_shot = two_node_engine();
        one_shot.run(1_000);

        let mut stepped = two_node_engine();
        while stepped.run(1) > 0 {}

        assert!(stepped.shared.collecting);
        assert_eq!(
            stepped.shared.net.payload_bytes(0),
            one_shot.shared.net.payload_bytes(0),
            "stepped runs must count the same link payload"
        );
        assert_eq!(stepped.shared.net.epoch_start, one_shot.shared.net.epoch_start);
        assert_eq!(stepped.shared.net.epoch_end, one_shot.shared.net.epoch_end);
        let (a, b) = (stepped.shared.net.bus_utility(0), one_shot.shared.net.bus_utility(0));
        assert!((a - b).abs() < 1e-12, "utilization {a} vs {b}");
    }

    #[test]
    fn fifo_tie_break_on_same_timestamp() {
        let mut q = EventQueue::default();
        q.schedule(5, 0, Payload::Timer(1, 0));
        q.schedule(5, 0, Payload::Timer(2, 0));
        q.schedule(3, 0, Payload::Timer(0, 0));
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.payload {
                Payload::Timer(t, _) => t,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(tags, vec![0, 1, 2]);
    }
}
