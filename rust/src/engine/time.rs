//! Simulation time base: unsigned 64-bit **picoseconds**.
//!
//! Picosecond granularity keeps every latency in the paper's Table III an
//! exact integer (1 ns bus time .. 40 ns controller time) while leaving
//! headroom for > 5 hours of simulated time, and integer time makes the
//! event order bit-reproducible.

/// Picoseconds.
pub type Ps = u64;

pub const PS: Ps = 1;
pub const NS: Ps = 1_000;
pub const US: Ps = 1_000_000;
pub const MS: Ps = 1_000_000_000;
pub const SEC: Ps = 1_000_000_000_000;

/// Nanoseconds (f64) -> picoseconds, rounding to nearest.
pub fn ns(v: f64) -> Ps {
    (v * NS as f64).round() as Ps
}

/// Microseconds (f64) -> picoseconds, rounding to nearest.
pub fn us(v: f64) -> Ps {
    (v * US as f64).round() as Ps
}

/// Picoseconds -> nanoseconds as f64 (for reporting).
pub fn to_ns(p: Ps) -> f64 {
    p as f64 / NS as f64
}

/// Serialization time of `bytes` at `gbps` gigabytes-per-second, in ps.
/// 1 GB/s == 1 byte/ns == 0.001 byte/ps.
pub fn ser_time(bytes: u64, gbps: f64) -> Ps {
    if gbps <= 0.0 {
        return 0; // "infinite bandwidth" configuration
    }
    ((bytes as f64) / gbps * NS as f64).round() as Ps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(ns(1.0), 1_000);
        assert_eq!(ns(0.5), 500);
        assert_eq!(us(1.0), 1_000_000);
        assert_eq!(us(45.0), 45 * US);
        assert_eq!(to_ns(2_500), 2.5);
    }

    #[test]
    fn serialization_time() {
        // 64B at 64 GB/s = 1 ns
        assert_eq!(ser_time(64, 64.0), NS);
        // 256B at 32 GB/s = 8 ns
        assert_eq!(ser_time(256, 32.0), 8 * NS);
        // infinite-bandwidth config
        assert_eq!(ser_time(4096, 0.0), 0);
    }
}
