//! Hot-path micro-benchmarks (harness=false; criterion unavailable
//! offline) — the perf-regression harness for the §Hot-path overhaul.
//!
//! Every stage that was rebuilt keeps its *before* implementation
//! selectable so the same binary measures both sides:
//!  * end-to-end DES throughput on the Fig 10 fully-connected /
//!    spine-leaf scale-16 systems, ladder queue vs the seed's binary
//!    heap (`EventQueue::reference_heap`);
//!  * event-queue churn in isolation (classic hold model);
//!  * routing: table construction (native BFS vs PJRT Pallas APSP) and
//!    per-hop `next_hop` lookup rate over the CSR arena;
//!  * snoop-filter insert/evict churn per victim policy on the slab;
//!  * DRAM backend access rate;
//!  * checkpoint mechanics (quiescent snapshot/restore cost, mid-run
//!    checkpointing overhead) and warm-start sweep speedup (K cells
//!    sharing one warm-up prefix, cold vs forked — byte-identical).
//!
//! `--json PATH` additionally dumps every number as a BENCH_*.json
//! datapoint (see EXPERIMENTS.md §Hot-path); `--quick` shrinks the op
//! counts for CI smoke use.

// Benchmarks measure host wall-clock by design (clippy.toml bans
// Instant::now in simulation code to keep wall time out of sim time).
#![allow(clippy::disallowed_methods)]

use esf::config::{build_system, BackendKind, SystemCfg};
use esf::devices::{Pattern, SnoopFilter, VictimPolicy};
use esf::engine::time::ns;
use esf::engine::{EventQueue, Payload};
use esf::engine::parallel::BarrierMode;
use esf::interconnect::{build, LinkCfg, NetState, Routing, Strategy, TopologyKind, WeightModel};
use esf::util::json::Json;
use esf::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::time::Instant;

fn obj(pairs: Vec<(String, Json)>) -> Json {
    Json::Obj(pairs.into_iter().collect::<BTreeMap<_, _>>())
}

fn e2e(kind: TopologyKind, reference_heap: bool, scale: u64) -> (u64, f64) {
    let mut cfg = SystemCfg::new(kind, 8);
    cfg.pattern = Pattern::Random;
    cfg.issue_interval = ns(1.0);
    cfg.queue_capacity = 128;
    cfg.requests_per_endpoint = 2000 * scale;
    cfg.warmup_fraction = 0.1;
    cfg.backend = BackendKind::Fixed(20.0);
    let mut sys = build_system(&cfg);
    if reference_heap {
        sys.engine.shared.queue = EventQueue::reference_heap();
    }
    let t0 = Instant::now();
    let events = sys.engine.run(u64::MAX);
    (events, t0.elapsed().as_secs_f64())
}

/// Hold model: steady-state queue of `hold` events, each pop schedules
/// one successor — the exact pattern the DES inner loop produces.
fn queue_churn(reference_heap: bool, hold: usize, ops: u64) -> f64 {
    let mut q = if reference_heap {
        EventQueue::reference_heap()
    } else {
        EventQueue::default()
    };
    let mut rng = Pcg32::new(7, 1);
    for _ in 0..hold {
        q.schedule(rng.gen_range(100_000), 0, Payload::Timer(0, 0));
    }
    let t0 = Instant::now();
    for _ in 0..ops {
        let ev = q.pop().expect("hold model never drains");
        q.schedule(ev.time + 1 + rng.gen_range(100_000), 0, Payload::Timer(0, 0));
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(q.len(), hold);
    ops as f64 / dt / 1e6
}

/// Intra-scenario scaling: ONE large spine-leaf fabric (scale 128 = 64
/// requesters + 64 memories + 34 switches = 162 nodes), sequential loop
/// vs the partitioned event-domain engine. Outputs are byte-identical
/// (tests/partition.rs); only wall-clock and the exchange accounting
/// (`Engine::intra_stats`) may move.
fn intra_e2e(
    intra_jobs: usize,
    scale: u64,
    mode: BarrierMode,
) -> (u64, f64, Option<esf::engine::IntraStats>) {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 64);
    cfg.pattern = Pattern::Random;
    cfg.issue_interval = ns(2.0);
    cfg.queue_capacity = 64;
    cfg.requests_per_endpoint = 250 * scale;
    cfg.warmup_fraction = 0.05;
    cfg.backend = BackendKind::Fixed(30.0);
    let mut sys = build_system(&cfg);
    let t0 = Instant::now();
    let events = if intra_jobs <= 1 {
        sys.engine.run(u64::MAX)
    } else {
        sys.engine.run_partitioned_opts(intra_jobs, WeightModel::Traffic, mode)
    };
    (events, t0.elapsed().as_secs_f64(), sys.engine.intra_stats)
}

/// Large-fabric scaling (the 1k/2k/4k-node curves): generated dragonfly
/// fabrics — N=400/800/1600 land exactly on 1000/2000/4000 nodes — with
/// a small fixed per-endpoint workload, sequential vs adaptive-barrier
/// partitioned at 2/4/8/16 domains.
fn large_e2e(
    n: usize,
    intra_jobs: usize,
    mode: BarrierMode,
) -> (u64, f64, Option<esf::engine::IntraStats>) {
    let mut cfg = SystemCfg::new(TopologyKind::Dragonfly, n);
    cfg.pattern = Pattern::Random;
    cfg.issue_interval = ns(2.0);
    cfg.queue_capacity = 32;
    cfg.requests_per_endpoint = 20;
    cfg.warmup_fraction = 0.05;
    cfg.backend = BackendKind::Fixed(30.0);
    let mut sys = build_system(&cfg);
    let t0 = Instant::now();
    let events = if intra_jobs <= 1 {
        sys.engine.run(u64::MAX)
    } else {
        sys.engine.run_partitioned_opts(intra_jobs, WeightModel::Traffic, mode)
    };
    (events, t0.elapsed().as_secs_f64(), sys.engine.intra_stats)
}

fn routing_lookups(strategy: Strategy, iters: u64) -> f64 {
    let fabric = build(TopologyKind::FullyConnected, 16, LinkCfg::default());
    let routing = Routing::build_bfs(&fabric.topo);
    let net = NetState::for_topology(&fabric.topo);
    let n = fabric.topo.n() as u64;
    let mut rng = Pcg32::new(3, 9);
    let mut acc = 0usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let u = rng.gen_range(n) as usize;
        let v = rng.gen_range(n) as usize;
        if let Some((w, _)) = routing.next_hop(u, u, v, strategy, &net, &fabric.topo, 0) {
            acc = acc.wrapping_add(w);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    iters as f64 / dt / 1e6
}

/// Random lines over 8x the filter capacity: most records miss, so this
/// measures the full needs_eviction/select_victim/clear/record cycle.
fn sf_churn(policy: VictimPolicy, ops: u64) -> f64 {
    let cap = 1024usize;
    let mut sf = SnoopFilter::new(cap, policy);
    let mut rng = Pcg32::new(11, 4);
    let t0 = Instant::now();
    for _ in 0..ops {
        let line = rng.gen_range(8 * cap as u64) * 64;
        if sf.needs_eviction(line) {
            let v = sf.select_victim().expect("full filter has a victim");
            sf.clear(&v);
        }
        sf.record(line, (line / 64 % 4) as usize);
    }
    let dt = t0.elapsed().as_secs_f64();
    sf.check_invariants().expect("filter consistent after churn");
    ops as f64 / dt / 1e6
}

fn main() {
    let args = esf::util::args::Args::from_env();
    let quick = args.has("quick");
    let scale: u64 = if quick { 1 } else { 4 };
    let mut json: Vec<(String, Json)> = Vec::new();

    // --- end-to-end DES throughput, ladder vs seed heap
    let mut e2e_json: Vec<(String, Json)> = Vec::new();
    for kind in [TopologyKind::FullyConnected, TopologyKind::SpineLeaf] {
        let (events, dt_heap) = e2e(kind, true, scale);
        let (events2, dt_ladder) = e2e(kind, false, scale);
        assert_eq!(events, events2, "queue impls must process identical events");
        let mh = events as f64 / dt_heap / 1e6;
        let ml = events as f64 / dt_ladder / 1e6;
        println!(
            "e2e {:<16} {:>9} events  heap {:.2} M ev/s  ladder {:.2} M ev/s  ({:+.1}% wall-clock)",
            kind.name(),
            events,
            mh,
            ml,
            (dt_ladder / dt_heap - 1.0) * 100.0
        );
        e2e_json.push((
            kind.name().to_string(),
            obj(vec![
                ("events".into(), Json::Num(events as f64)),
                ("heap_mevps".into(), Json::Num(mh)),
                ("ladder_mevps".into(), Json::Num(ml)),
                ("wallclock_delta".into(), Json::Num(dt_ladder / dt_heap - 1.0)),
            ]),
        ));
    }
    json.push(("e2e".into(), obj(e2e_json)));

    // --- intra-scenario scaling: partitioned event domains on one
    // >=128-node fabric (the PR 4 headline datapoint, PR 5
    // traffic-weighted + sparse exchange)
    {
        let mut ij: Vec<(String, Json)> = Vec::new();
        let mut ex: Vec<(String, Json)> = Vec::new();
        let (events_seq, dt_seq, _) = intra_e2e(1, scale, BarrierMode::Adaptive);
        println!(
            "intra spine-leaf-128 jobs=1 {:>9} events  {:>6.2}s  (sequential reference)",
            events_seq, dt_seq
        );
        ij.push(("events".into(), Json::Num(events_seq as f64)));
        ij.push(("seq_wall_s".into(), Json::Num(dt_seq)));
        for jobs in [2usize, 4, 8] {
            let (events_par, dt_par, stats) = intra_e2e(jobs, scale, BarrierMode::Adaptive);
            assert_eq!(
                events_seq, events_par,
                "partitioned run must process identical events"
            );
            println!(
                "intra spine-leaf-128 jobs={jobs} {:>9} events  {:>6.2}s  ({:.2}x)",
                events_par,
                dt_par,
                dt_seq / dt_par
            );
            ij.push((format!("jobs{jobs}_wall_s"), Json::Num(dt_par)));
            ij.push((format!("jobs{jobs}_speedup"), Json::Num(dt_seq / dt_par)));
            // Exchange volume: adaptive barrier (widened windows, elided
            // quiet tokens) vs the PR 5 fixed-window protocol on the
            // same workload. Deterministic counts (pure function of
            // topology + workload), not timings.
            let s = stats.expect("162-node spine-leaf must partition");
            let (events_fixed, _, fstats) = intra_e2e(jobs, scale, BarrierMode::FixedWindow);
            assert_eq!(events_seq, events_fixed, "fixed-window run diverged");
            let f = fstats.expect("fixed-window stats");
            let a2a = s.domains * (s.domains - 1);
            let reduction = 1.0 - s.messages as f64 / f.messages.max(1) as f64;
            println!(
                "intra exchange jobs={jobs}: {} domains, {} channels \
                 (all-to-all {a2a}), adaptive {} msgs / {} windows \
                 ({} widened, {} tokens elided) vs fixed {} msgs \
                 ({} quiet) / {} windows: {:.0}% fewer messages",
                s.domains,
                s.channels,
                s.messages,
                s.windows,
                s.widened_windows,
                s.elided_tokens,
                f.messages,
                f.quiet_messages,
                f.windows,
                100.0 * reduction
            );
            ex.push((
                format!("jobs{jobs}"),
                obj(vec![
                    ("domains".into(), Json::Num(s.domains as f64)),
                    ("channels".into(), Json::Num(s.channels as f64)),
                    ("all_to_all_channels".into(), Json::Num(a2a as f64)),
                    ("windows".into(), Json::Num(s.windows as f64)),
                    ("widened_windows".into(), Json::Num(s.widened_windows as f64)),
                    ("messages".into(), Json::Num(s.messages as f64)),
                    ("quiet_messages".into(), Json::Num(s.quiet_messages as f64)),
                    ("elided_tokens".into(), Json::Num(s.elided_tokens as f64)),
                    (
                        "events_exchanged".into(),
                        Json::Num(s.events_exchanged as f64),
                    ),
                    ("fixed_windows".into(), Json::Num(f.windows as f64)),
                    ("fixed_messages".into(), Json::Num(f.messages as f64)),
                    (
                        "fixed_quiet_messages".into(),
                        Json::Num(f.quiet_messages as f64),
                    ),
                    ("message_reduction".into(), Json::Num(reduction)),
                ]),
            ));
        }
        json.push(("intra_scaling".into(), obj(ij)));
        json.push(("intra_exchange".into(), obj(ex)));
    }

    // --- large-fabric scaling: 1k/2k/4k-node dragonfly, adaptive
    // barrier at 2/4/8/16 domains (quick mode keeps only the 1k point)
    {
        let mut lj: Vec<(String, Json)> = Vec::new();
        let sizes: &[usize] = if quick { &[400] } else { &[400, 800, 1600] };
        for &n in sizes {
            let mut nj: Vec<(String, Json)> = Vec::new();
            let (events_seq, dt_seq, _) = large_e2e(n, 1, BarrierMode::Adaptive);
            let nodes = n * 5 / 2;
            println!(
                "large dragonfly-{nodes} jobs=1 {:>9} events  {:>6.2}s  (sequential reference)",
                events_seq, dt_seq
            );
            nj.push(("nodes".into(), Json::Num(nodes as f64)));
            nj.push(("events".into(), Json::Num(events_seq as f64)));
            nj.push(("seq_wall_s".into(), Json::Num(dt_seq)));
            for jobs in [2usize, 4, 8, 16] {
                let (events_par, dt_par, stats) = large_e2e(n, jobs, BarrierMode::Adaptive);
                assert_eq!(events_seq, events_par, "large partitioned run diverged");
                let s = stats.expect("dragonfly must partition");
                println!(
                    "large dragonfly-{nodes} jobs={jobs} {:>9} events  {:>6.2}s  ({:.2}x)  \
                     {} msgs / {} windows ({} widened, {} elided)",
                    events_par,
                    dt_par,
                    dt_seq / dt_par,
                    s.messages,
                    s.windows,
                    s.widened_windows,
                    s.elided_tokens
                );
                nj.push((
                    format!("jobs{jobs}"),
                    obj(vec![
                        ("wall_s".into(), Json::Num(dt_par)),
                        ("speedup".into(), Json::Num(dt_seq / dt_par)),
                        ("domains".into(), Json::Num(s.domains as f64)),
                        ("channels".into(), Json::Num(s.channels as f64)),
                        ("windows".into(), Json::Num(s.windows as f64)),
                        ("widened_windows".into(), Json::Num(s.widened_windows as f64)),
                        ("messages".into(), Json::Num(s.messages as f64)),
                        ("elided_tokens".into(), Json::Num(s.elided_tokens as f64)),
                        (
                            "events_exchanged".into(),
                            Json::Num(s.events_exchanged as f64),
                        ),
                    ]),
                ));
            }
            lj.push((format!("n{nodes}"), obj(nj)));
        }
        json.push(("intra_scaling_large".into(), obj(lj)));
    }

    // --- speculative barrier A/B: optimistic stints vs the adaptive
    // default. Quiet cuts (sparse issue stream / few global links) are
    // where speculation pays — rounds are short and the stint work
    // overlaps barrier latency. The hot spine-leaf cut is the honest
    // adversarial row: near-every stint is invalidated by a straggler,
    // so capture + re-execution costs make speculation LOSE there.
    // That row is why Adaptive stays the default.
    {
        let mut spj: Vec<(String, Json)> = Vec::new();
        let spec_row = |s: &esf::engine::IntraStats, events: u64, dt_a: f64, dt_s: f64| {
            let executed = events + s.wasted_events;
            obj(vec![
                ("adaptive_wall_s".into(), Json::Num(dt_a)),
                ("speculative_wall_s".into(), Json::Num(dt_s)),
                ("speedup_vs_adaptive".into(), Json::Num(dt_a / dt_s)),
                ("stints".into(), Json::Num(s.speculative_windows as f64)),
                ("rollbacks".into(), Json::Num(s.rollbacks as f64)),
                (
                    "rollback_rate".into(),
                    Json::Num(s.rollbacks as f64 / s.speculative_windows.max(1) as f64),
                ),
                ("wasted_events".into(), Json::Num(s.wasted_events as f64)),
                (
                    "wasted_event_frac".into(),
                    Json::Num(s.wasted_events as f64 / executed.max(1) as f64),
                ),
                (
                    "commit_advances".into(),
                    Json::Num(s.committed_frontier_advances as f64),
                ),
            ])
        };
        for (name, issue_ns) in [("spine_leaf_quiet", 16.0), ("spine_leaf_hot", 2.0)] {
            let run = |jobs: usize, mode: BarrierMode| {
                let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 64);
                cfg.pattern = Pattern::Random;
                cfg.issue_interval = ns(issue_ns);
                cfg.queue_capacity = 64;
                cfg.requests_per_endpoint = 250 * scale;
                cfg.warmup_fraction = 0.05;
                cfg.backend = BackendKind::Fixed(30.0);
                let mut sys = build_system(&cfg);
                let t0 = Instant::now();
                let events = sys.engine.run_partitioned_opts(jobs, WeightModel::Traffic, mode);
                (events, t0.elapsed().as_secs_f64(), sys.engine.intra_stats)
            };
            let mut cj: Vec<(String, Json)> = Vec::new();
            for jobs in [4usize, 8] {
                let (ea, dt_a, _) = run(jobs, BarrierMode::Adaptive);
                let (es, dt_s, stats) = run(jobs, BarrierMode::Speculative);
                assert_eq!(ea, es, "speculative run must process identical events");
                let s = stats.expect("spine-leaf must partition");
                println!(
                    "spec {name:<16} jobs={jobs} adaptive {dt_a:>6.2}s  speculative {dt_s:>6.2}s \
                     ({:.2}x)  {} stints / {} rollbacks, {} wasted",
                    dt_a / dt_s,
                    s.speculative_windows,
                    s.rollbacks,
                    s.wasted_events
                );
                cj.push((format!("jobs{jobs}"), spec_row(&s, ea, dt_a, dt_s)));
            }
            spj.push((name.to_string(), obj(cj)));
        }
        // 1000-node dragonfly: the large-fabric low-traffic cut — few
        // global links per group pair, so cross-domain crossings are
        // rare relative to intra-group work.
        {
            let mut cj: Vec<(String, Json)> = Vec::new();
            for jobs in [4usize, 16] {
                let (ea, dt_a, _) = large_e2e(400, jobs, BarrierMode::Adaptive);
                let (es, dt_s, stats) = large_e2e(400, jobs, BarrierMode::Speculative);
                assert_eq!(ea, es, "speculative large run must process identical events");
                let s = stats.expect("dragonfly must partition");
                println!(
                    "spec dragonfly-1000  jobs={jobs} adaptive {dt_a:>6.2}s  speculative \
                     {dt_s:>6.2}s ({:.2}x)  {} stints / {} rollbacks, {} wasted",
                    dt_a / dt_s,
                    s.speculative_windows,
                    s.rollbacks,
                    s.wasted_events
                );
                cj.push((format!("jobs{jobs}"), spec_row(&s, ea, dt_a, dt_s)));
            }
            spj.push(("dragonfly_1000".to_string(), obj(cj)));
        }
        json.push(("intra_speculative".into(), obj(spj)));
    }

    // --- checkpoints + warm-start prefix sharing
    {
        use esf::engine::snapshot::SnapMeta;
        use esf::sweep::{
            results_json, run_scenarios_cached_opts, run_scenarios_opts, Scenario, SweepCache,
        };
        let mut wj: Vec<(String, Json)> = Vec::new();
        let meta_for = |cfg: &SystemCfg, quiescent: bool| SnapMeta {
            cfg_fingerprint: cfg.fingerprint(),
            prefix_fingerprint: cfg.prefix_fingerprint(),
            prefix_canon: cfg.prefix_canon(),
            quiescent,
        };

        // Snapshot mechanics on the 162-node intra fabric (same system
        // as the intra_scaling rows): serialized size, quiescent
        // snapshot + restore cost, and the wall overhead of writing a
        // mid-run checkpoint per 1/64th of simulated time.
        let mut base = SystemCfg::new(TopologyKind::SpineLeaf, 64);
        base.pattern = Pattern::Random;
        base.issue_interval = ns(2.0);
        base.queue_capacity = 64;
        base.requests_per_endpoint = 250 * scale;
        base.warmup_fraction = 0.05;
        base.backend = BackendKind::Fixed(30.0);
        let mut sys = build_system(&base);
        sys.engine.run_until_collecting();
        let t0 = Instant::now();
        let snap = sys.engine.snapshot(&meta_for(&base, true));
        let snapshot_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut fresh = build_system(&base);
        let t0 = Instant::now();
        fresh.engine.restore(&snap).expect("bench snapshot must restore");
        let restore_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "checkpoint spine-leaf-162: {} bytes  snapshot {snapshot_ms:.2} ms  restore {restore_ms:.2} ms",
            snap.len()
        );
        wj.push(("snapshot_bytes".into(), Json::Num(snap.len() as f64)));
        wj.push(("snapshot_ms".into(), Json::Num(snapshot_ms)));
        wj.push(("restore_ms".into(), Json::Num(restore_ms)));

        // Buffer-reusing capture path (`Engine::snapshot_into`) — what
        // the speculative engine's rollback checkpoints and any periodic
        // checkpointer actually pay once the buffer has warmed to
        // capacity: same bytes, no per-capture allocation.
        let meta = meta_for(&base, true);
        let mut buf = Vec::new();
        sys.engine.snapshot_into(&mut buf, &meta);
        let reps: u32 = if quick { 5 } else { 20 };
        let t0 = Instant::now();
        for _ in 0..reps {
            sys.engine.snapshot_into(&mut buf, &meta);
        }
        let snapshot_into_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        assert_eq!(buf, snap, "buffer-reusing snapshot must be byte-identical");
        println!(
            "checkpoint spine-leaf-162: snapshot_into warm buffer {snapshot_into_ms:.2} ms \
             (vs {snapshot_ms:.2} ms allocating)"
        );
        wj.push(("snapshot_into_warm_ms".into(), Json::Num(snapshot_into_ms)));

        let mut s1 = build_system(&base);
        let t0 = Instant::now();
        s1.engine.run(u64::MAX);
        let straight_s = t0.elapsed().as_secs_f64();
        let slice = (s1.engine.shared.now / 64).max(1);
        let ckpt_path =
            std::env::temp_dir().join(format!("esf-bench-ckpt-{}.snap", std::process::id()));
        let mmeta = meta_for(&base, false);
        let mut s2 = build_system(&base);
        let t0 = Instant::now();
        let mut bound = slice;
        let mut snapshots = 0u64;
        loop {
            s2.engine.run_until(bound);
            bound += slice;
            if s2.engine.shared.queue.is_empty() {
                break;
            }
            std::fs::write(&ckpt_path, s2.engine.snapshot(&mmeta)).expect("write checkpoint");
            snapshots += 1;
        }
        let ckpt_s = t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_file(&ckpt_path);
        assert_eq!(
            s1.engine.events_processed, s2.engine.events_processed,
            "checkpoint stepping loop must not perturb the run"
        );
        println!(
            "checkpoint-every spine-leaf-162: {snapshots} snapshots  straight {straight_s:.2}s  \
             checkpointed {ckpt_s:.2}s  ({:+.1}% wall)",
            (ckpt_s / straight_s - 1.0) * 100.0
        );
        wj.push((
            "midrun".into(),
            obj(vec![
                ("snapshots".into(), Json::Num(snapshots as f64)),
                ("straight_wall_s".into(), Json::Num(straight_s)),
                ("checkpoint_wall_s".into(), Json::Num(ckpt_s)),
                ("overhead".into(), Json::Num(ckpt_s / straight_s - 1.0)),
            ]),
        ));

        // Warm-start sweeps: K read_ratio cells share one warm-up
        // prefix; cold (uncached) vs warm (cold cache dir — the prefix
        // simulates once and forks K times). Default warm-up fraction
        // (0.25), so Amdahl caps the speedup at 1/(1 - 0.25*(K-1)/K).
        let mut sweep_base = SystemCfg::new(TopologyKind::SpineLeaf, 16);
        sweep_base.pattern = Pattern::Random;
        sweep_base.issue_interval = ns(2.0);
        sweep_base.queue_capacity = 64;
        sweep_base.requests_per_endpoint = 600 * scale;
        sweep_base.backend = BackendKind::Fixed(30.0);
        let ks: &[usize] = if quick { &[3] } else { &[3, 6, 12] };
        for &k in ks {
            let cells = || -> Vec<Scenario> {
                (0..k)
                    .map(|i| {
                        let mut cfg = sweep_base.clone();
                        cfg.read_ratio = 1.0 - i as f64 * 0.05;
                        Scenario {
                            label: format!("rr={:.2}", cfg.read_ratio),
                            cfg,
                        }
                    })
                    .collect()
            };
            let t0 = Instant::now();
            let cold = run_scenarios_opts(cells(), 1, 1);
            let cold_s = t0.elapsed().as_secs_f64();
            let dir = std::env::temp_dir()
                .join(format!("esf-bench-warm-{}-{k}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            let cache = SweepCache::open(&dir).expect("bench cache dir");
            let t0 = Instant::now();
            let warm = run_scenarios_cached_opts(cells(), 1, 1, &cache);
            let warm_s = t0.elapsed().as_secs_f64();
            let _ = std::fs::remove_dir_all(&dir);
            assert_eq!(
                results_json(&cold).to_string(),
                results_json(&warm).to_string(),
                "warm-start sweep output diverged from cold"
            );
            println!(
                "warm-start k={k:<2} cold {cold_s:>6.2}s  warm {warm_s:>6.2}s  ({:.2}x)",
                cold_s / warm_s
            );
            wj.push((
                format!("k{k}"),
                obj(vec![
                    ("cells".into(), Json::Num(k as f64)),
                    ("cold_wall_s".into(), Json::Num(cold_s)),
                    ("warm_wall_s".into(), Json::Num(warm_s)),
                    ("speedup".into(), Json::Num(cold_s / warm_s)),
                ]),
            ));
        }
        json.push(("warm_start".into(), obj(wj)));
    }

    // --- event queue hold-model churn
    {
        let ops = 1_000_000 * scale;
        let mut qj: Vec<(String, Json)> = Vec::new();
        for hold in [256usize, 4096, 65536] {
            let heap = queue_churn(true, hold, ops);
            let ladder = queue_churn(false, hold, ops);
            println!(
                "queue hold={:<6} heap {:>6.1} M ops/s  ladder {:>6.1} M ops/s  ({:.2}x)",
                hold,
                heap,
                ladder,
                ladder / heap
            );
            qj.push((
                format!("hold_{hold}"),
                obj(vec![
                    ("heap_mops".into(), Json::Num(heap)),
                    ("ladder_mops".into(), Json::Num(ladder)),
                ]),
            ));
        }
        json.push(("queue_churn".into(), obj(qj)));
    }

    // --- routing construction
    let mut rj: Vec<(String, Json)> = Vec::new();
    // Small fully-connected points pin the scratch-reuse fix; the
    // 1000-node dragonfly point pins large-fabric construction cost.
    let fabrics = [4, 8, 16]
        .map(|n| build(TopologyKind::FullyConnected, n, LinkCfg::default()))
        .into_iter()
        .chain([build(TopologyKind::Dragonfly, 400, LinkCfg::default())]);
    for fabric in fabrics {
        let iters = if fabric.topo.n() >= 1000 { 10 } else { 100 };
        let t0 = Instant::now();
        for _ in 0..iters {
            let _ = Routing::build_bfs(&fabric.topo);
        }
        let bfs = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "routing bfs      {:>4} nodes: {:.1} us/build",
            fabric.topo.n(),
            bfs * 1e6
        );
        rj.push((
            format!("build_bfs_us_n{}", fabric.topo.n()),
            Json::Num(bfs * 1e6),
        ));
    }
    if let Ok(mut rt) = esf::runtime::Runtime::load_default() {
        let fabric = build(TopologyKind::FullyConnected, 16, LinkCfg::default());
        let n = fabric.topo.n();
        let adj = fabric.topo.adjacency_matrix(esf::runtime::UNREACH);
        let _ = rt.apsp(&adj, n); // compile once
        let t0 = Instant::now();
        let iters = 20;
        for _ in 0..iters {
            let _ = rt.apsp(&adj, n).unwrap();
        }
        let pjrt = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "routing pjrt-apsp {:>3} nodes: {:.1} us/build (compiled)",
            n,
            pjrt * 1e6
        );
        rj.push((format!("build_pjrt_us_n{n}"), Json::Num(pjrt * 1e6)));
    }

    // --- routing next_hop lookup rate (CSR arena hot path)
    for (name, strategy) in [
        ("oblivious", Strategy::Oblivious),
        ("adaptive", Strategy::Adaptive),
    ] {
        let mops = routing_lookups(strategy, 1_000_000 * scale);
        println!("routing next_hop {name:<10} {mops:>6.1} M lookups/s");
        rj.push((format!("lookup_{name}_mops"), Json::Num(mops)));
    }
    json.push(("routing".into(), obj(rj)));

    // --- snoop-filter churn per policy (slab + intrusive lists)
    {
        let mut sj: Vec<(String, Json)> = Vec::new();
        let mut policies = VictimPolicy::BASIC.to_vec();
        policies.push(VictimPolicy::BlockLen { max_len: 4 });
        for policy in policies {
            let ops = match policy {
                // victim scans are O(capacity); fewer ops keep runtime flat
                VictimPolicy::Lfi | VictimPolicy::BlockLen { .. } => 100_000 * scale,
                _ => 400_000 * scale,
            };
            let mops = sf_churn(policy, ops);
            println!("snoop filter {:<9} {mops:>6.2} M record+evict/s", policy.name());
            sj.push((format!("{}_mops", policy.name()), Json::Num(mops)));
        }
        json.push(("snoop_filter".into(), obj(sj)));
    }

    // --- DRAM backend
    {
        use esf::devices::memdev::MemBackend;
        use esf::dram::{DramBackend, DramCfg};
        let mut d = DramBackend::new(DramCfg::ddr5_4800());
        let mut rng = Pcg32::new(1, 0);
        let n = 2_000_000u64;
        let t0 = Instant::now();
        let mut at = 0;
        for _ in 0..n {
            at = d.access(rng.gen_range(1 << 28) & !63, false, at);
        }
        let dt = t0.elapsed().as_secs_f64();
        let maps = n as f64 / dt / 1e6;
        println!("dram backend: {maps:.1} M accesses/s (host)");
        json.push((
            "dram".into(),
            obj(vec![("host_maccess_per_s".into(), Json::Num(maps))]),
        ));
    }

    if let Some(path) = args.get("json") {
        let doc = obj(vec![
            ("bench".into(), Json::Str("hotpath".into())),
            ("quick".into(), Json::Bool(quick)),
            (
                "machine".into(),
                Json::Str(args.str_or("machine", "unknown").to_string()),
            ),
            ("results".into(), obj(json)),
        ]);
        std::fs::write(path, format!("{doc}\n")).expect("write bench json");
        println!("wrote {path}");
    }
}
