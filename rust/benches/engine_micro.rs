//! Engine micro-benchmarks (harness=false; criterion unavailable offline).
//!
//! Measures the L3 hot paths the §Perf pass optimizes:
//!  * end-to-end DES throughput (events/second) on the Fig 10
//!    fully-connected scale-16 system — the busiest preset;
//!  * routing table construction (native BFS vs PJRT Pallas APSP);
//!  * event queue push/pop;
//!  * DRAM backend access rate.

use esf::config::{build_system, BackendKind, SystemCfg};
use esf::devices::Pattern;
use esf::engine::time::ns;
use esf::interconnect::TopologyKind;
use std::time::Instant;

fn main() {
    // --- end-to-end events/sec
    for kind in [TopologyKind::FullyConnected, TopologyKind::SpineLeaf] {
        let mut cfg = SystemCfg::new(kind, 8);
        cfg.pattern = Pattern::Random;
        cfg.issue_interval = ns(1.0);
        cfg.queue_capacity = 128;
        cfg.requests_per_endpoint = 2000;
        cfg.warmup_fraction = 0.1;
        cfg.backend = BackendKind::Fixed(20.0);
        let mut sys = build_system(&cfg);
        let t0 = Instant::now();
        let events = sys.engine.run(u64::MAX);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "e2e {:<16} {:>9} events in {:.3}s = {:.2} M events/s",
            kind.name(),
            events,
            dt,
            events as f64 / dt / 1e6
        );
    }

    // --- routing construction
    for n in [4, 8, 16] {
        let fabric = esf::interconnect::build(
            TopologyKind::FullyConnected,
            n,
            esf::interconnect::LinkCfg::default(),
        );
        let t0 = Instant::now();
        let iters = 100;
        for _ in 0..iters {
            let _ = esf::interconnect::Routing::build_bfs(&fabric.topo);
        }
        let bfs = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "routing bfs      {:>4} nodes: {:.1} us/build",
            fabric.topo.n(),
            bfs * 1e6
        );
    }
    if let Ok(mut rt) = esf::runtime::Runtime::load_default() {
        let fabric = esf::interconnect::build(
            TopologyKind::FullyConnected,
            16,
            esf::interconnect::LinkCfg::default(),
        );
        let n = fabric.topo.n();
        let adj = fabric.topo.adjacency_matrix(esf::runtime::UNREACH);
        let _ = rt.apsp(&adj, n); // compile once
        let t0 = Instant::now();
        let iters = 20;
        for _ in 0..iters {
            let _ = rt.apsp(&adj, n).unwrap();
        }
        let pjrt = t0.elapsed().as_secs_f64() / iters as f64;
        println!("routing pjrt-apsp {:>3} nodes: {:.1} us/build (compiled)", n, pjrt * 1e6);
    }

    // --- event queue
    {
        use esf::engine::{EventQueue, Payload};
        let mut q = EventQueue::default();
        let t0 = Instant::now();
        let n = 2_000_000u64;
        for i in 0..n {
            q.schedule(i.wrapping_mul(0x9E3779B97F4A7C15) % 1_000_000, 0, Payload::Timer(0, i));
        }
        while q.len() > 0 {
            let _ = q.len();
            break;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("event queue: {:.1} M push/s", n as f64 / dt / 1e6);
    }

    // --- DRAM backend
    {
        use esf::devices::memdev::MemBackend;
        use esf::dram::{DramBackend, DramCfg};
        use esf::util::rng::Pcg32;
        let mut d = DramBackend::new(DramCfg::ddr5_4800());
        let mut rng = Pcg32::new(1, 0);
        let n = 2_000_000u64;
        let t0 = Instant::now();
        let mut at = 0;
        for _ in 0..n {
            at = d.access(rng.gen_range(1 << 28) & !63, false, at);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("dram backend: {:.1} M accesses/s (host)", n as f64 / dt / 1e6);
    }
}
