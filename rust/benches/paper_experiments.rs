//! `cargo bench` — regenerates every table and figure of the paper's
//! evaluation and times each harness. criterion is unavailable in the
//! offline crate set, so this is a plain harness=false bench binary: it
//! prints the same rows/series the paper reports plus wall-clock timing.
//!
//! Pass `--full` for paper-scale request counts (slower).

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let quick = !full;
    let mut total = std::time::Duration::ZERO;
    for (id, desc) in esf::experiments::list() {
        let t0 = std::time::Instant::now();
        let tables = esf::experiments::run(id, quick).expect("known id");
        let dt = t0.elapsed();
        total += dt;
        println!("### {id} — {desc}   [{:.2}s]", dt.as_secs_f64());
        for t in tables {
            println!("{}", t.render());
        }
    }
    println!("=== all {} experiments in {:.1}s ({}) ===",
        esf::experiments::list().len(),
        total.as_secs_f64(),
        if quick { "quick mode; pass --full for paper-scale" } else { "full mode" },
    );
}
