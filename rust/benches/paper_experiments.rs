//! `cargo bench` — regenerates every table and figure of the paper's
//! evaluation and times each harness. criterion is unavailable in the
//! offline crate set, so this is a plain harness=false bench binary: it
//! prints the same rows/series the paper reports plus wall-clock timing.
//!
//! Pass `--full` for paper-scale request counts (slower), and
//! `--jobs N` to shard each harness's config grid over N worker threads
//! (0 = all cores; results are identical, only wall-clock changes).

// Benchmarks measure host wall-clock by design (clippy.toml bans
// Instant::now in simulation code to keep wall time out of sim time).
#![allow(clippy::disallowed_methods)]

fn main() {
    let args = esf::util::args::Args::from_env();
    let quick = !args.has("full");
    let jobs = args.u64_or("jobs", 1) as usize;
    let mut total = std::time::Duration::ZERO;
    for (id, desc) in esf::experiments::list() {
        let t0 = std::time::Instant::now();
        let tables = esf::experiments::run_jobs(id, quick, jobs).expect("known id");
        let dt = t0.elapsed();
        total += dt;
        println!("### {id} — {desc}   [{:.2}s]", dt.as_secs_f64());
        for t in tables {
            println!("{}", t.render());
        }
    }
    println!("=== all {} experiments in {:.1}s ({}) ===",
        esf::experiments::list().len(),
        total.as_secs_f64(),
        if quick { "quick mode; pass --full for paper-scale" } else { "full mode" },
    );
}
