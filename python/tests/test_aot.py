"""AOT path tests: the lowered HLO text must round-trip through the XLA
client available at build time and reproduce the oracle's numbers — the
same contract the Rust runtime relies on."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.minplus import UNREACH

jax.config.update("jax_platform_name", "cpu")


def test_apsp_hlo_text_parses_and_names_entry():
    text = aot.lower_apsp(16)
    assert "ENTRY" in text
    assert "f32[16,16]" in text


def test_tracestats_hlo_text_parses():
    text = aot.lower_tracestats(8, 100)
    assert "ENTRY" in text
    assert "f32[8,3]" in text or "f32[8,100]" in text


@pytest.mark.parametrize("n", [16, 32])
def test_apsp_executable_matches_oracle(n):
    """Compile the lowered HLO via the build-time XLA client and execute —
    this mirrors exactly what the Rust PJRT path does."""
    from jax._src.lib import xla_client as xc

    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    lowered = jax.jit(lambda a: model.apsp(a)).lower(spec)
    compiled = lowered.compile()

    rng = np.random.default_rng(n)
    adj = np.full((n, n), UNREACH, dtype=np.float32)
    np.fill_diagonal(adj, 0.0)
    # ring + a few chords
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1.0
    for i in range(0, n, 4):
        j = (i + n // 2) % n
        adj[i, j] = adj[j, i] = 1.0

    (got,) = compiled(jnp.asarray(adj))
    want = ref.floyd_warshall_ref(adj)
    want = jnp.where(want >= UNREACH / 2, UNREACH, want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_manifest_written(tmp_path):
    import json
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--sizes", "16"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    m = json.loads((tmp_path / "manifest.json").read_text())
    assert "16" in m["apsp"]
    assert (tmp_path / "apsp_16.hlo.txt").exists()
