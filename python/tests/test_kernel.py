"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/values; assert_allclose against ref.py.
This is the CORE correctness signal for the AOT artifacts the Rust runtime
executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.minplus import minplus, UNREACH
from compile.kernels.tracestats import tracestats
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_dist_matrix(rng: np.random.Generator, n: int, density: float) -> np.ndarray:
    """Random symmetric 'graph-like' distance matrix with UNREACH holes."""
    m = rng.uniform(1.0, 100.0, size=(n, n)).astype(np.float32)
    mask = rng.uniform(size=(n, n)) > density
    m[mask] = UNREACH
    m = np.minimum(m, m.T)
    np.fill_diagonal(m, 0.0)
    return m


# ---------------------------------------------------------------- minplus

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([2, 3, 4, 7, 8, 16, 31, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_minplus_matches_ref_random(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 50.0, size=(n, n)).astype(np.float32)
    y = rng.uniform(0.0, 50.0, size=(n, n)).astype(np.float32)
    got = minplus(jnp.asarray(x), jnp.asarray(y))
    want = ref.minplus_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16, 32]),
    density=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_minplus_graphlike_with_unreach(n, density, seed):
    rng = np.random.default_rng(seed)
    x = rand_dist_matrix(rng, n, density)
    got = minplus(jnp.asarray(x), jnp.asarray(x))
    want = ref.minplus_ref(jnp.asarray(x), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("block", [8, 16, 32, 64])
def test_minplus_block_shapes_agree(block):
    """Tiling must not change the result (64 is a multiple of all blocks)."""
    rng = np.random.default_rng(7)
    x = rng.uniform(0.0, 10.0, size=(64, 64)).astype(np.float32)
    got = minplus(jnp.asarray(x), jnp.asarray(x), block=block)
    want = ref.minplus_ref(jnp.asarray(x), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_minplus_identity():
    """Min-plus identity matrix: 0 diagonal, UNREACH elsewhere."""
    n = 16
    ident = np.full((n, n), UNREACH, dtype=np.float32)
    np.fill_diagonal(ident, 0.0)
    rng = np.random.default_rng(3)
    x = rng.uniform(0.0, 100.0, size=(n, n)).astype(np.float32)
    got = minplus(jnp.asarray(x), jnp.asarray(ident))
    np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)


def test_minplus_nonmultiple_block_falls_back():
    """n not a multiple of block -> whole-array single block, same result."""
    rng = np.random.default_rng(11)
    x = rng.uniform(0.0, 10.0, size=(10, 10)).astype(np.float32)
    got = minplus(jnp.asarray(x), jnp.asarray(x), block=32)
    want = ref.minplus_ref(jnp.asarray(x), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ------------------------------------------------------------------- apsp

@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16, 32]),
    density=st.floats(0.15, 0.6),
    seed=st.integers(0, 2**31 - 1),
)
def test_apsp_matches_floyd_warshall(n, density, seed):
    from compile.model import apsp

    rng = np.random.default_rng(seed)
    adj = rand_dist_matrix(rng, n, density)
    (got,) = apsp(jnp.asarray(adj))
    want = ref.floyd_warshall_ref(adj)
    # Clamp oracle's unreachable band like the production path does.
    want = jnp.where(want >= UNREACH / 2, UNREACH, want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_apsp_chain_topology():
    """Chain of 8 nodes: distance(i, j) == |i - j|."""
    from compile.model import apsp

    n = 8
    adj = np.full((n, n), UNREACH, dtype=np.float32)
    np.fill_diagonal(adj, 0.0)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = 1.0
    (got,) = apsp(jnp.asarray(adj))
    want = np.abs(np.arange(n)[:, None] - np.arange(n)[None, :]).astype(np.float32)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


def test_apsp_disconnected_stays_unreachable():
    from compile.model import apsp

    n = 16
    adj = np.full((n, n), UNREACH, dtype=np.float32)
    np.fill_diagonal(adj, 0.0)
    # two cliques, no bridge
    for grp in (range(0, 8), range(8, 16)):
        for i in grp:
            for j in grp:
                if i != j:
                    adj[i, j] = 1.0
    (got,) = apsp(jnp.asarray(adj))
    got = np.asarray(got)
    assert np.all(got[:8, 8:] == UNREACH)
    assert np.all(got[8:, :8] == UNREACH)
    assert np.all(got[:8, :8] <= 1.0)


# ------------------------------------------------------------- tracestats

@settings(max_examples=20, deadline=None)
@given(
    w=st.integers(1, 8),
    l=st.sampled_from([8, 64, 100]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tracestats_matches_ref(w, l, seed):
    rng = np.random.default_rng(seed)
    is_write = (rng.uniform(size=(w, l)) < 0.3).astype(np.float32)
    nbytes = rng.choice([64.0, 128.0, 256.0], size=(w, l)).astype(np.float32)
    got = tracestats(jnp.asarray(is_write), jnp.asarray(nbytes))
    want = ref.tracestats_ref(jnp.asarray(is_write), jnp.asarray(nbytes))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_tracestats_counts_sum_to_window_len():
    rng = np.random.default_rng(5)
    w, l = 4, 100
    is_write = (rng.uniform(size=(w, l)) < 0.5).astype(np.float32)
    nbytes = np.full((w, l), 64.0, dtype=np.float32)
    got = np.asarray(tracestats(jnp.asarray(is_write), jnp.asarray(nbytes)))
    np.testing.assert_allclose(got[:, 0] + got[:, 1], l)
    np.testing.assert_allclose(got[:, 2], l * 64.0)
