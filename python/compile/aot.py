"""AOT lowering: JAX/Pallas -> HLO *text* -> artifacts/.

Run once at build time (`make artifacts`); the Rust runtime loads the text
with `HloModuleProto::from_text_file` and compiles it on the PJRT CPU
client. HLO TEXT, not `.serialize()`: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 rejects (proto.id() <=
INT_MAX); the text parser reassigns ids and round-trips cleanly.

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fabric sizes (total node count incl. switches) we pre-lower APSP for. The
# Rust interconnect layer pads its adjacency matrix up to the next size; >256
# node fabrics fall back to the native Dijkstra path.
APSP_SIZES = (16, 32, 64, 128, 256)
# Trace-stat window shapes: (windows, window_len). Fig 20b uses 1000-access
# windows over 1M-access traces.
TRACESTAT_SHAPES = ((1000, 1000), (256, 1000))


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_apsp(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    block = 32 if n % 32 == 0 else n
    lowered = jax.jit(lambda a: model.apsp(a, block=block)).lower(spec)
    return to_hlo_text(lowered)


def lower_tracestats(w: int, l: int) -> str:
    spec = jax.ShapeDtypeStruct((w, l), jnp.float32)
    lowered = jax.jit(model.windowed_trace_stats).lower(spec, spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", type=int, nargs="*", default=list(APSP_SIZES))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest: dict = {"apsp": {}, "tracestats": {}}
    for n in args.sizes:
        path = f"apsp_{n}.hlo.txt"
        text = lower_apsp(n)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["apsp"][str(n)] = {
            "path": path,
            "n": n,
            "input": f"f32[{n},{n}]",
            "output": f"(f32[{n},{n}],)",
        }
        print(f"apsp n={n}: {len(text)} chars -> {path}")

    for w, l in TRACESTAT_SHAPES:
        path = f"tracestats_{w}x{l}.hlo.txt"
        text = lower_tracestats(w, l)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["tracestats"][f"{w}x{l}"] = {
            "path": path,
            "windows": w,
            "window_len": l,
            "input": f"2 x f32[{w},{l}]",
            "output": f"(f32[{w},3],)",
        }
        print(f"tracestats {w}x{l}: {len(text)} chars -> {path}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest -> {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
