"""Layer-1 Pallas kernel: windowed trace statistics.

Fig 20b of the paper correlates per-window (1000 accesses) bandwidth with
the read/write "mix degree" of real-world traces. The reduction over a long
trace is embarrassingly parallel across windows; this kernel computes, per
window, the read count, write count, and total payload bytes in one pass.

Grid: one program per window row. Each block is a full (window_len,) lane;
the reduction is a VPU-friendly sum. interpret=True on CPU (see minplus.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tracestats_kernel(is_write_ref, bytes_ref, o_ref):
    w = is_write_ref[...]  # (1, L) f32 in {0, 1}
    b = bytes_ref[...]     # (1, L) f32
    writes = jnp.sum(w, axis=1)
    reads = jnp.sum(1.0 - w, axis=1)
    total = jnp.sum(b, axis=1)
    o_ref[...] = jnp.stack([reads, writes, total], axis=1)  # (1, 3)


@jax.jit
def tracestats(is_write: jax.Array, nbytes: jax.Array) -> jax.Array:
    """Per-window [reads, writes, total_bytes] for (W, L) trace windows."""
    w_, l_ = is_write.shape
    assert nbytes.shape == (w_, l_)
    return pl.pallas_call(
        _tracestats_kernel,
        grid=(w_,),
        in_specs=[
            pl.BlockSpec((1, l_), lambda i: (i, 0)),
            pl.BlockSpec((1, l_), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w_, 3), jnp.float32),
        interpret=True,
    )(is_write, nbytes)
