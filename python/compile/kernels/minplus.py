"""Layer-1 Pallas kernel: tiled min-plus matrix "multiplication".

The interconnect layer's routing-table construction is all-pairs shortest
path (APSP) over the fabric graph. APSP by repeated matrix squaring uses the
(min, +) semiring in place of (+, *):

    D'[i, j] = min_k ( D[i, k] + D[k, j] )

This kernel computes one min-plus contraction, tiled for a TPU-style memory
hierarchy: the grid is (i, j, k) over (bm, bn, bk) blocks; the (i, j) output
block is *revisited* across the k dimension and accumulates with `min`,
exactly like an MXU matmul accumulates with `+`. The MXU systolic array
cannot evaluate a (min, +) contraction, so the inner block op targets the
VPU with 8x128-aligned tiles; BlockSpec expresses the HBM<->VMEM schedule.

On CPU this must run with interpret=True (real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute) -- see DESIGN.md
SSHardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# A "no edge" distance. Finite (not jnp.inf) so that inf + inf overflow and
# NaN propagation cannot occur inside the accumulation; anything >= UNREACH/2
# is treated as unreachable by the Rust consumer.
UNREACH = 1.0e9


def _minplus_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output block: min over the current bk slab.

    x_ref: (bm, bk) block of D
    y_ref: (bk, bn) block of D
    o_ref: (bm, bn) accumulator block (revisited across grid axis 2)
    """
    k = pl.program_id(2)
    x = x_ref[...]
    y = y_ref[...]
    # (bm, bk, bn) broadcast add, then min-reduce the k axis. VMEM footprint
    # is bm*bk*bn * 4B; block sizes are chosen in `minplus` to keep this
    # within a TPU core's VMEM budget (see DESIGN.md).
    partial = jnp.min(x[:, :, None] + y[None, :, :], axis=1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k != 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("block",))
def minplus(x: jax.Array, y: jax.Array, *, block: int = 32) -> jax.Array:
    """Min-plus product of two square f32 matrices via the Pallas kernel.

    `block` is the (bm = bn = bk) tile edge; inputs whose dimension is not a
    multiple of `block` fall back to a single whole-array block.
    """
    n = x.shape[0]
    assert x.shape == (n, n) and y.shape == (n, n), (x.shape, y.shape)
    b = block if n % block == 0 and n >= block else n
    grid = (n // b, n // b, n // b)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b), lambda i, j, k: (i, k)),
            pl.BlockSpec((b, b), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y)
