"""Pure-jnp correctness oracles for the Pallas kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reference min-plus product: D'[i,j] = min_k x[i,k] + y[k,j]."""
    return jnp.min(x[:, :, None] + y[None, :, :], axis=1)


def apsp_ref(adj: jax.Array) -> jax.Array:
    """Reference APSP by repeated min-plus squaring (same contraction count
    as the production path, but via the jnp oracle)."""
    n = adj.shape[0]
    d = adj
    steps = max(1, (n - 1).bit_length())
    for _ in range(steps):
        d = minplus_ref(d, d)
    return d


def floyd_warshall_ref(adj) -> jax.Array:
    """Independent O(N^3) Floyd-Warshall oracle (different algorithm shape,
    same answer) used to cross-check apsp_ref itself."""
    import numpy as np

    d = np.array(adj, dtype=np.float64)
    n = d.shape[0]
    for k in range(n):
        d = np.minimum(d, d[:, k : k + 1] + d[k : k + 1, :])
    return jnp.asarray(d, dtype=jnp.float32)


def tracestats_ref(is_write: jax.Array, nbytes: jax.Array) -> jax.Array:
    writes = jnp.sum(is_write, axis=1)
    reads = jnp.sum(1.0 - is_write, axis=1)
    total = jnp.sum(nbytes, axis=1)
    return jnp.stack([reads, writes, total], axis=1).astype(jnp.float32)
