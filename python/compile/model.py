"""Layer-2 JAX model: APSP routing-table construction for the interconnect
layer, composed from the Layer-1 Pallas min-plus kernel.

The interconnect layer receives a fabric adjacency matrix (link cost = 1 per
hop by default, UNREACH for absent links, 0 on the diagonal) and needs the
full distance matrix to derive per-switch PBR next-hop tables. Distances are
computed by ceil(log2(N-1)) min-plus squarings; each squaring is one Pallas
kernel launch.

These functions are lowered ONCE by aot.py to HLO text; the Rust runtime
loads and executes them via PJRT. Python is never on the simulation path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.minplus import minplus, UNREACH
from .kernels.tracestats import tracestats


def apsp(adj: jax.Array, *, block: int = 32) -> tuple[jax.Array]:
    """All-pairs shortest path distances from an (N, N) f32 adjacency matrix.

    Entries: 0 on the diagonal, link cost for direct links, >= UNREACH/2 for
    "no edge". Returns a 1-tuple (the AOT interchange contract lowers with
    return_tuple=True).
    """
    n = adj.shape[0]
    d = adj
    # After s squarings paths of length 2^s are covered; the longest simple
    # path has n-1 edges.
    steps = max(1, (n - 1).bit_length())
    for _ in range(steps):
        d = minplus(d, d, block=block)
    # Clamp the unreachable band so repeated additions cannot creep toward
    # f32 precision loss on the Rust side.
    d = jnp.where(d >= UNREACH / 2, UNREACH, d)
    return (d,)


def windowed_trace_stats(is_write: jax.Array, nbytes: jax.Array) -> tuple[jax.Array]:
    """Per-window [reads, writes, total_bytes] over (W, L) windows (Fig 20b)."""
    return (tracestats(is_write, nbytes),)
